//! SGD trainer for the MLP + dataset plumbing + weight persistence.
//!
//! The §4.1 experiment trains the 784-256-128-64-10 network, quantizes the
//! last (64×10) layer, and measures accuracy vs the number of quantization
//! values. Training here is momentum-SGD with minibatches over the
//! procedural digit corpus; the trained model is cached on disk so the
//! figure harnesses don't retrain per sweep point.

use super::mlp::Mlp;
use crate::data::rng::Pcg32;
use crate::data::synth_digits::{DigitDataset, PIXELS};
use crate::linalg::matrix::Matrix;
use crate::{Error, Result};
use std::io::{BufRead, Write};
use std::path::Path;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Learning rate.
    pub lr: f64,
    /// Momentum coefficient.
    pub momentum: f64,
    /// Minibatch size.
    pub batch: usize,
    /// Number of full passes over the training set.
    pub epochs: usize,
    /// Shuffle seed.
    pub seed: u64,
    /// Print progress every this many steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { lr: 0.08, momentum: 0.9, batch: 64, epochs: 12, seed: 0, log_every: 0 }
    }
}

/// Training result.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Final mean loss over the last epoch.
    pub final_loss: f64,
    /// Per-epoch mean losses (the loss curve).
    pub loss_curve: Vec<f64>,
    /// Training-set accuracy after training.
    pub train_accuracy: f64,
    /// Steps executed.
    pub steps: usize,
}

/// Stack a dataset into a design matrix + label vector.
pub fn to_matrix(ds: &DigitDataset) -> (Matrix, Vec<usize>) {
    let n = ds.len();
    let mut x = Matrix::zeros(n, PIXELS);
    let mut labels = Vec::with_capacity(n);
    for (i, img) in ds.images.iter().enumerate() {
        x.row_mut(i).copy_from_slice(&img.pixels);
        labels.push(img.label);
    }
    (x, labels)
}

/// Train in place with momentum SGD.
pub fn train(mlp: &mut Mlp, ds: &DigitDataset, cfg: &TrainConfig) -> Result<TrainReport> {
    if ds.is_empty() {
        return Err(Error::InvalidInput("train: empty dataset".into()));
    }
    if cfg.batch == 0 {
        return Err(Error::InvalidParam("train: batch must be ≥ 1".into()));
    }
    let (x, labels) = to_matrix(ds);
    let n = ds.len();
    let mut rng = Pcg32::new(cfg.seed, 8080);
    let mut order: Vec<usize> = (0..n).collect();

    // Momentum buffers.
    let mut vel_w: Vec<Matrix> = mlp
        .layers
        .iter()
        .map(|l| Matrix::zeros(l.w.rows(), l.w.cols()))
        .collect();
    let mut vel_b: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();

    let mut loss_curve = Vec::with_capacity(cfg.epochs);
    let mut steps = 0usize;
    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut batches = 0usize;
        for chunk in order.chunks(cfg.batch) {
            // Gather the batch.
            let mut xb = Matrix::zeros(chunk.len(), PIXELS);
            let mut yb = Vec::with_capacity(chunk.len());
            for (bi, &i) in chunk.iter().enumerate() {
                xb.row_mut(bi).copy_from_slice(x.row(i));
                yb.push(labels[i]);
            }
            let (logits, cache) = mlp.forward(&xb)?;
            let (loss, grads) = mlp.loss_and_grad(&cache, &logits, &yb)?;
            epoch_loss += loss;
            batches += 1;
            steps += 1;

            for (li, layer) in mlp.layers.iter_mut().enumerate() {
                let vw = &mut vel_w[li];
                for ((v, w), g) in vw
                    .data_mut()
                    .iter_mut()
                    .zip(layer.w.data_mut())
                    .zip(grads.dw[li].data())
                {
                    *v = cfg.momentum * *v - cfg.lr * g;
                    *w += *v;
                }
                for ((v, b), g) in vel_b[li].iter_mut().zip(&mut layer.b).zip(&grads.db[li]) {
                    *v = cfg.momentum * *v - cfg.lr * g;
                    *b += *v;
                }
            }
            if cfg.log_every > 0 && steps % cfg.log_every == 0 {
                eprintln!("epoch {epoch} step {steps}: loss {loss:.4}");
            }
        }
        loss_curve.push(epoch_loss / batches.max(1) as f64);
    }

    let train_accuracy = mlp.accuracy(&x, &labels)?;
    Ok(TrainReport {
        final_loss: *loss_curve.last().unwrap_or(&f64::NAN),
        loss_curve,
        train_accuracy,
        steps,
    })
}

/// Evaluate accuracy on a dataset.
pub fn evaluate(mlp: &Mlp, ds: &DigitDataset) -> Result<f64> {
    let (x, labels) = to_matrix(ds);
    mlp.accuracy(&x, &labels)
}

/// Persist weights to a simple line-oriented text format (layer dims +
/// values). Human-greppable and dependency-free.
pub fn save_weights(mlp: &Mlp, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "sqlsq-mlp-v1 {}", mlp.layers.len())?;
    for l in &mlp.layers {
        writeln!(f, "layer {} {} {}", l.w.rows(), l.w.cols(), if l.relu { 1 } else { 0 })?;
        for v in l.w.data() {
            writeln!(f, "{:e}", v)?;
        }
        for v in &l.b {
            writeln!(f, "{:e}", v)?;
        }
    }
    Ok(())
}

/// Load weights saved by [`save_weights`].
pub fn load_weights(path: &Path) -> Result<Mlp> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut lines = f.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::InvalidInput("empty weight file".into()))??;
    let mut hp = header.split_whitespace();
    if hp.next() != Some("sqlsq-mlp-v1") {
        return Err(Error::InvalidInput("bad weight file magic".into()));
    }
    let n_layers: usize = hp
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Error::InvalidInput("bad layer count".into()))?;

    let mut layers = Vec::with_capacity(n_layers);
    let next_val = |lines: &mut dyn Iterator<Item = std::io::Result<String>>| -> Result<f64> {
        let line = lines
            .next()
            .ok_or_else(|| Error::InvalidInput("truncated weight file".into()))??;
        line.trim()
            .parse()
            .map_err(|e| Error::InvalidInput(format!("bad float: {e}")))
    };
    for _ in 0..n_layers {
        let meta = lines
            .next()
            .ok_or_else(|| Error::InvalidInput("truncated weight file".into()))??;
        let mut mp = meta.split_whitespace();
        if mp.next() != Some("layer") {
            return Err(Error::InvalidInput("expected layer header".into()));
        }
        let rows: usize = mp.next().and_then(|s| s.parse().ok()).unwrap_or(0);
        let cols: usize = mp.next().and_then(|s| s.parse().ok()).unwrap_or(0);
        let relu = mp.next() == Some("1");
        if rows == 0 || cols == 0 {
            return Err(Error::InvalidInput("bad layer dims".into()));
        }
        let mut w = Matrix::zeros(rows, cols);
        for i in 0..rows * cols {
            w.data_mut()[i] = next_val(&mut lines)?;
        }
        let mut b = vec![0.0; cols];
        for bi in b.iter_mut() {
            *bi = next_val(&mut lines)?;
        }
        layers.push(super::mlp::Dense { w, b, relu });
    }
    Ok(Mlp { layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_digits::{generate, CLASSES};

    #[test]
    fn training_learns_digits() {
        // Small net + small corpus: must clearly beat chance quickly.
        let ds = generate(300, 1);
        let mut mlp = Mlp::new(&[PIXELS, 32, CLASSES], 2);
        let report = train(
            &mut mlp,
            &ds,
            &TrainConfig { epochs: 6, lr: 0.1, ..Default::default() },
        )
        .unwrap();
        assert!(
            report.train_accuracy > 0.7,
            "train accuracy too low: {}",
            report.train_accuracy
        );
        // Loss curve trends down.
        assert!(report.loss_curve.last().unwrap() < &report.loss_curve[0]);
        // Generalizes to a held-out jittered set.
        let test = generate(100, 99);
        let acc = evaluate(&mlp, &test).unwrap();
        assert!(acc > 0.5, "test accuracy too low: {acc}");
    }

    #[test]
    fn save_load_roundtrip() {
        let mut mlp = Mlp::new(&[PIXELS, 16, CLASSES], 3);
        let ds = generate(50, 4);
        train(&mut mlp, &ds, &TrainConfig { epochs: 1, ..Default::default() }).unwrap();
        let dir = std::env::temp_dir().join("sqlsq_test_weights");
        let path = dir.join("mlp.txt");
        save_weights(&mlp, &path).unwrap();
        let loaded = load_weights(&path).unwrap();
        assert_eq!(loaded.layers.len(), mlp.layers.len());
        for (a, b) in loaded.layers.iter().zip(&mlp.layers) {
            assert_eq!(a.relu, b.relu);
            assert!(a.w.max_abs_diff(&b.w) < 1e-12);
            for (x, y) in a.b.iter().zip(&b.b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("sqlsq_test_badweights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "not a weight file\n").unwrap();
        assert!(load_weights(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_config() {
        let ds = generate(10, 5);
        let mut mlp = Mlp::new(&[PIXELS, 4, CLASSES], 6);
        assert!(train(
            &mut mlp,
            &ds,
            &TrainConfig { batch: 0, ..Default::default() }
        )
        .is_err());
        assert!(train(&mut mlp, &DigitDataset::default(), &TrainConfig::default()).is_err());
    }
}
