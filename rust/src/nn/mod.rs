//! Neural-network substrate (S17): the paper's 784-256-128-64-10 MLP with
//! manual backprop and a momentum-SGD trainer, used by the §4.1
//! quantization-accuracy experiments and the end-to-end example.

pub mod mlp;
pub mod train;
