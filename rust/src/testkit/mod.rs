//! Property-testing kit (S22) — the offline substitute for proptest
//! (DESIGN §2).
//!
//! A property is a function from a generated input to `Result<(), String>`.
//! The driver runs `cases` deterministic cases; on failure it *shrinks*
//! vector inputs by halving and element-simplification before reporting
//! the minimal failing case it found.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use sqlsq::testkit::{check, gens};
//! check("sorted after sort", 64, gens::vec_f64(0..=32, -5.0, 5.0), |xs| {
//!     let mut s = xs.clone();
//!     s.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     if s.windows(2).all(|p| p[0] <= p[1]) { Ok(()) } else { Err("not sorted".into()) }
//! });
//! ```

use crate::data::rng::Pcg32;

/// A generator produces a value from an RNG.
pub trait Gen<T> {
    /// Generate one value.
    fn generate(&self, rng: &mut Pcg32) -> T;
}

impl<T, F: Fn(&mut Pcg32) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Pcg32) -> T {
        self(rng)
    }
}

/// Things the driver knows how to shrink.
pub trait Shrink: Sized + Clone {
    /// Candidate simpler versions of `self` (ordered most-aggressive
    /// first).
    fn shrink_candidates(&self) -> Vec<Self>;
}

impl Shrink for Vec<f64> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n > 1 {
            out.push(self[..n / 2].to_vec());
            out.push(self[n / 2..].to_vec());
            let mut dropped = self.clone();
            dropped.pop();
            out.push(dropped);
        }
        // Value simplification: round everything to 2 decimals.
        if self.iter().any(|x| (x * 100.0).round() / 100.0 != *x) {
            out.push(self.iter().map(|x| (x * 100.0).round() / 100.0).collect());
        }
        out
    }
}

impl Shrink for (Vec<f64>, usize) {
    fn shrink_candidates(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink_candidates()
            .into_iter()
            .map(|v| (v, self.1))
            .collect();
        if self.1 > 1 {
            out.push((self.0.clone(), self.1 / 2));
        }
        out
    }
}

/// Run a property over `cases` generated inputs; panics with the minimal
/// failing input on violation. Base seed fixed per property name for
/// reproducibility.
pub fn check<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    // Seed derived from the property name → independent, reproducible.
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Pcg32::seeded(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink loop: greedily accept the first failing candidate.
            let mut best = input;
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in best.shrink_candidates() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, shrunk): {best_msg}\ninput: {best:?}"
            );
        }
    }
}

/// Common generators.
pub mod gens {
    use super::*;
    use std::ops::RangeInclusive;

    /// Vector of uniform f64 with length drawn from `len`.
    pub fn vec_f64(
        len: RangeInclusive<usize>,
        lo: f64,
        hi: f64,
    ) -> impl Fn(&mut Pcg32) -> Vec<f64> {
        move |rng| {
            let span = len.end() - len.start();
            let n = len.start() + if span > 0 { rng.gen_range(span + 1) } else { 0 };
            (0..n.max(1)).map(|_| rng.uniform(lo, hi)).collect()
        }
    }

    /// Vector with clustered structure (groups of near-identical values) —
    /// the shape quantization cares about.
    pub fn vec_clustered(
        len: RangeInclusive<usize>,
        groups: usize,
    ) -> impl Fn(&mut Pcg32) -> Vec<f64> {
        move |rng| {
            let span = len.end() - len.start();
            let n = (len.start() + if span > 0 { rng.gen_range(span + 1) } else { 0 }).max(1);
            let centers: Vec<f64> = (0..groups.max(1)).map(|_| rng.uniform(0.0, 10.0)).collect();
            (0..n)
                .map(|_| {
                    let c = centers[rng.gen_range(centers.len())];
                    c + rng.normal_with(0.0, 0.05)
                })
                .collect()
        }
    }

    /// (vector, target count) pairs.
    pub fn vec_with_target(
        len: RangeInclusive<usize>,
        max_target: usize,
    ) -> impl Fn(&mut Pcg32) -> (Vec<f64>, usize) {
        let inner = vec_f64(len, -10.0, 10.0);
        move |rng| {
            let v = inner(rng);
            let t = 1 + rng.gen_range(max_target.max(1));
            (v, t)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, gens::vec_f64(1..=16, -1.0, 1.0), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_shrunk_input() {
        check("must fail", 50, gens::vec_f64(8..=32, -1.0, 1.0), |xs| {
            if xs.len() < 2 {
                Ok(())
            } else {
                Err("len ≥ 2".into())
            }
        });
    }

    #[test]
    fn shrinking_reduces_length() {
        let v: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let cands = v.shrink_candidates();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Pcg32::seeded(1);
        let g = gens::vec_f64(3..=7, -2.0, 2.0);
        for _ in 0..100 {
            let v = g(&mut rng);
            assert!((3..=7).contains(&v.len()));
            assert!(v.iter().all(|&x| (-2.0..2.0).contains(&x)));
        }
        let gt = gens::vec_with_target(1..=4, 8);
        for _ in 0..100 {
            let (v, t) = gt(&mut rng);
            assert!(!v.is_empty());
            assert!((1..=8).contains(&t));
        }
    }

    #[test]
    fn deterministic_given_name() {
        // Two runs of the same property see the same cases: we detect this
        // by recording the first generated vector.
        use std::sync::Mutex;
        let seen: Mutex<Vec<Vec<f64>>> = Mutex::new(Vec::new());
        for _ in 0..2 {
            let first = Mutex::new(None::<Vec<f64>>);
            check("det-check", 1, gens::vec_f64(4..=4, 0.0, 1.0), |xs| {
                *first.lock().unwrap() = Some(xs.clone());
                Ok(())
            });
            seen.lock().unwrap().push(first.into_inner().unwrap().unwrap());
        }
        let s = seen.into_inner().unwrap();
        assert_eq!(s[0], s[1]);
    }
}
