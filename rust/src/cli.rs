//! Command-line interface (S23). Hand-rolled argument parsing (clap is
//! unavailable offline — DESIGN §2).
//!
//! ```text
//! sqlsq quantize  --method l1_ls --values 8 [--lambda1 x] [--input f | --demo]
//! sqlsq sweep     --method l1_ls [--steps 16] [--lambda-min 1e-4] [--lambda-max 1e-1]
//! sqlsq train     [--cache path]
//! sqlsq eval      <fig1|...|fig8|crossover|ablations|bitwidth|oor|all>
//! sqlsq serve     --jobs 200 [--engine native|runtime|auto] [--workers N]
//! sqlsq selfcheck [--artifacts dir]
//! sqlsq version | help
//! ```

use crate::config::{CachePolicy, Config, Engine};
use crate::coordinator::Coordinator;
use crate::eval::{figures, workloads};
use crate::jsonio::{self, Json};
use crate::quant::{self, CompressionStats, QuantMethod, QuantOptions};
use crate::runtime::BackendKind;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed command line: subcommand + flags.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional (the subcommand).
    pub command: String,
    /// Remaining positionals.
    pub positionals: Vec<String>,
    /// `--key value` flags.
    pub flags: BTreeMap<String, String>,
}

/// Parse raw args (excluding argv[0]).
pub fn parse_args(raw: &[String]) -> Result<Args> {
    let mut args = Args::default();
    let mut it = raw.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(), // boolean flag
            };
            args.flags.insert(key.to_string(), value);
        } else if args.command.is_empty() {
            args.command = a.clone();
        } else {
            args.positionals.push(a.clone());
        }
    }
    Ok(args)
}

impl Args {
    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn flag_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad number '{v}'"))),
        }
    }

    fn flag_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad number '{v}'"))),
        }
    }
}

const HELP: &str = "\
sqlsq — Scalar Quantization as Sparse Least Square Optimization (full-system repro)

USAGE:
  sqlsq quantize  --method <id> [--values K] [--lambda1 X] [--lambda2 Y]
                  [--input FILE | --demo] [--clamp lo,hi] [--seed N]
                  [--weights FILE] [--entropy-budget BITS]
                  [--precision f32|f64] [--output codebook|values|FILE]
  sqlsq sweep     --method <id> [--steps N] [--lambda-min X] [--lambda-max Y]
                  [--values K] [--cold] [--input FILE | --demo]
                  [--precision f32|f64] [--output codebook|values]
  sqlsq matvec    [--rows N] [--cols N] [--grouping per_tensor|per_row|per_column]
                  [--method <id>] [--bits B1,B2,..] [--norm-tol X] [--seed N]
                  [--output json|FILE]
  sqlsq train     [--cache PATH]
  sqlsq eval      <fig1|...|fig8|crossover|ablations|bitwidth|oor|all>
                  [--report-dir DIR]
  sqlsq serve     [--jobs N] [--engine native|runtime|auto] [--workers N]
                  [--artifacts DIR] [--precision f32|f64]
                  [--runtime-backend pjrt|shadow] [--runtime-fanout N]
                  [--lanes N] [--cache lru|off] [--cache-bytes N]
                  [--distinct N]
  sqlsq listen    [--addr HOST:PORT] [--workers N] [--engine native|runtime|auto]
                  [--max-conns N] [--tenant-rate R] [--tenant-burst B]
                  [--shed-retry-ms MS] [--cache lru|off] [--cache-bytes N]
                  [--cache-shared true|false] [--duration-secs S]
  sqlsq loadgen   [--addr HOST:PORT] [--jobs N] [--conns C] [--tenants T]
                  [--codec json|binary] [--distinct D] [--n N] [--seed S]
  sqlsq selfcheck [--artifacts DIR]
  sqlsq version | help

METHODS: l1, l1_ls, l1_l2, l0, iter_l1, cluster_ls, kmeans, kmeans_exact,
         gmm, data_transform, tv_exact, agglom, fcm

PRECISION: --precision f32 runs the native single-precision lane (native
         f32 kernels for the CD family; other methods widen internally).

OUTPUT: --output codebook emits the compact wire format as JSON (a few
         shared levels + one small index per element — what a serving
         edge should ship), including a "stats" compression-accounting
         object (bits/value, entropy, compact-vs-dense bytes; spec in
         the jsonio module docs / README "Wire format"); --output values
         emits the full-length vector(s). On quantize, any other value
         is treated as a file path and written in the historical values
         format (the default prints only the summary, exactly as before).

WEIGHTS: --weights FILE supplies one non-negative importance weight per
         input element (same text format as --input); the solve then
         minimizes the weighted objective Σ wᵢ(xᵢ−qᵢ)². Uniform weights
         reproduce the unweighted result bitwise. --entropy-budget BITS
         greedily merges codebook levels until the index entropy fits
         the budget (entropy-constrained quantization); the stats block
         reports the entropy-coded size either way.

BACKENDS: --runtime-backend pjrt executes AOT artifacts (make artifacts);
         shadow replays the kernels natively with runtime semantics — no
         artifacts needed, and batches fan across --runtime-fanout
         sub-lanes.

CACHE:   the serve path keeps a result cache keyed by a content
         fingerprint of (payload bits, lane, method, options); an
         identical resubmit is answered from the cached compact result —
         bitwise-identical, no solve. --cache off disables it;
         --cache-bytes bounds the compact bytes retained (LRU). serve's
         synthetic traffic cycles --distinct payload/option units across
         --jobs submits, so --jobs > --distinct is repeat-heavy and the
         metrics line shows the hit rate.

NETWORK: sqlsq listen serves the coordinator over TCP (length-prefixed
         frames, json or binary payloads, tenant id in the frame header;
         see README \"Network serving\"). Backpressure answers SHED with a
         retry-after hint instead of stalling; --tenant-rate/--tenant-burst
         add per-tenant token-bucket fairness; --cache-shared false
         partitions the result cache by tenant. --duration-secs S drains
         gracefully after S seconds (0 = run until killed). sqlsq loadgen
         offers a deterministic multi-tenant mix against a listener and
         prints latency percentiles, throughput and shed rate.

MATVEC: quantized-compute demo — builds a residual cascade (QMatrix) over
         a synthetic weight matrix, prints the per-level error-vs-bits
         table, races the packed matvec against decode-then-dense, and
         reports cascade compression accounting. --bits lists the index
         widths per level (default 4,2,2); --norm-tol stops a group's
         cascade once its relative residual norm falls below X. --output
         json prints the qmatrix wire form; any other value writes it to
         that file.";

/// CLI entry (returns the process exit code).
pub fn run() -> i32 {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&raw) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// Testable dispatcher.
pub fn dispatch(raw: &[String]) -> Result<()> {
    let args = parse_args(raw)?;
    match args.command.as_str() {
        "" | "help" => {
            println!("{HELP}");
            Ok(())
        }
        "version" => {
            println!("sqlsq {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "quantize" => cmd_quantize(&args),
        "sweep" => cmd_sweep(&args),
        "matvec" => cmd_matvec(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "listen" => cmd_listen(&args),
        "loadgen" => cmd_loadgen(&args),
        "selfcheck" => cmd_selfcheck(&args),
        other => Err(Error::Config(format!("unknown command '{other}' (try help)"))),
    }
}

fn parse_precision(args: &Args) -> Result<quant::Precision> {
    match args.flag("precision") {
        None => Ok(quant::Precision::F64),
        Some(v) => quant::Precision::from_id(v)
            .ok_or_else(|| Error::Config(format!("--precision wants f32|f64, got '{v}'"))),
    }
}

/// Parse a text file of numbers: comma/space/tab separated, `#` comments.
/// Shared by `--input` and `--weights`.
fn parse_number_file(path: &str) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)?;
    let mut data = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        for tok in t.split([',', ' ', '\t']).filter(|s| !s.is_empty()) {
            data.push(tok.parse().map_err(|_| {
                Error::InvalidInput(format!("{path}:{}: bad number '{tok}'", ln + 1))
            })?);
        }
    }
    Ok(data)
}

fn load_input(args: &Args) -> Result<Vec<f64>> {
    if let Some(path) = args.flag("input") {
        parse_number_file(path)
    } else {
        // --demo (default): the Figure-5 digit image.
        Ok(workloads::digit_image())
    }
}

/// `--entropy-budget BITS` as an `Option<f64>` (validation of the value
/// itself lives in `quant::validate_entropy_budget`, shared with the
/// serve path).
fn parse_entropy_budget(args: &Args) -> Result<Option<f64>> {
    match args.flag("entropy-budget") {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| Error::Config(format!("--entropy-budget: bad number '{v}'"))),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let method_id = args.flag("method").unwrap_or("l1_ls");
    let method = QuantMethod::from_id(method_id)
        .ok_or_else(|| Error::Config(format!("unknown method '{method_id}'")))?;
    let data = load_input(args)?;
    let clamp = match args.flag("clamp") {
        None => None,
        Some(v) => {
            let (a, b) = v
                .split_once(',')
                .ok_or_else(|| Error::Config("--clamp wants lo,hi".into()))?;
            Some((
                a.parse().map_err(|_| Error::Config("bad clamp lo".into()))?,
                b.parse().map_err(|_| Error::Config("bad clamp hi".into()))?,
            ))
        }
    };
    let opts = QuantOptions {
        lambda1: args.flag_f64("lambda1", 1e-2)?,
        lambda2: args.flag_f64("lambda2", 0.0)?,
        target_values: args.flag_usize("values", 16)?,
        seed: args.flag_usize("seed", 0)? as u64,
        clamp,
        precision: parse_precision(args)?,
        entropy_budget: parse_entropy_budget(args)?,
        ..Default::default()
    };
    let weights = args.flag("weights").map(parse_number_file).transpose()?;
    let n = data.len();
    let distinct_in = crate::linalg::stats::distinct_count_exact(&data);
    let precision = opts.precision;
    let requested = opts.target_values;
    // One front door: a single-vector request through the Quantizer. The
    // owned input moves into the request — no slice copy — and the
    // response is codebook-first (full values only materialize below if
    // the output mode needs them).
    let t0 = std::time::Instant::now();
    let mut req = quant::QuantRequest::vector(data).method(method).options(opts);
    if let Some(w) = weights {
        req = req.weights(w);
    }
    let item = quant::Quantizer::new().run(&req)?.into_single()?;
    let dt = t0.elapsed();
    let stats = item.compression(requested);
    println!("method            : {}", method.id());
    println!("precision         : {}", precision.id());
    println!("input length      : {n}");
    println!("distinct in       : {distinct_in}");
    println!("distinct out      : {}", item.distinct_values());
    println!("l2 loss           : {:.6e}", item.l2_loss());
    println!("clamped values    : {}", item.clamped());
    println!("iterations        : {}", item.diag().iterations);
    println!("nnz / lambda1     : {} / {:.3e}", item.diag().nnz, item.diag().lambda1);
    println!(
        "bits/value        : {:.3} (idx {}→{} bits stored→packed, entropy {:.3})",
        stats.bits_per_value,
        stats.bits_per_idx_stored,
        stats.bits_per_idx_packed,
        stats.index_entropy
    );
    println!(
        "compact vs dense  : {} B vs {} B ({:.2}x)",
        stats.compact_bytes, stats.dense_bytes, stats.byte_ratio
    );
    println!("entropy-coded     : {} B (size model at H(index))", stats.entropy_coded_bytes);
    println!("time              : {:?}", dt);
    match args.flag("output") {
        Some("codebook") => {
            let extra = vec![("stats", jsonio::stats_to_json(&stats))];
            println!("{}", jsonio::codebook_to_json(&item.codebook_f64(), extra).to_string());
        }
        Some("values") => {
            println!("{}", jsonio::values_to_json(&item.materialize_f64(), Vec::new()).to_string());
        }
        Some(path) => {
            // Historical behavior: any other value is a file path for the
            // full-vector text format.
            let text: String =
                item.materialize_f64().iter().map(|v| format!("{v}\n")).collect();
            std::fs::write(path, text)?;
            println!("wrote             : {path}");
        }
        None => {}
    }
    Ok(())
}

/// λ sweep through the request front door: one [`quant::QuantRequest`]
/// with a sweep plan — the prepare stage runs once and warm starts ride
/// the grid (pass `--cold` for independent cold solves).
fn cmd_sweep(args: &Args) -> Result<()> {
    let method_id = args.flag("method").unwrap_or("l1_ls");
    let method = QuantMethod::from_id(method_id)
        .ok_or_else(|| Error::Config(format!("unknown method '{method_id}'")))?;
    let data = load_input(args)?;
    let steps = args.flag_usize("steps", 16)?;
    let lo = args.flag_f64("lambda-min", 1e-4)?;
    let hi = args.flag_f64("lambda-max", 1e-1)?;
    let warm = args.flag("cold").is_none();
    let output = match args.flag("output") {
        None => None,
        Some(v @ ("codebook" | "values")) => Some(v),
        Some(other) => {
            return Err(Error::Config(format!(
                "--output wants codebook|values, got '{other}'"
            )))
        }
    };
    let precision = parse_precision(args)?;
    let lambdas = workloads::lambda_grid(lo, hi, steps)?;
    let opts = QuantOptions {
        lambda2: args.flag_f64("lambda2", 0.0)?,
        target_values: args.flag_usize("values", 16)?,
        seed: args.flag_usize("seed", 0)? as u64,
        precision,
        ..Default::default()
    };

    let n = data.len();
    // Report the problem size the solver actually sees: on the f32 lane,
    // distinct f64 values can collapse after narrowing. Display-only, and
    // costs one extra sort of the CLI input (the run's own prepared input
    // is not exposed through the response).
    let m = match precision {
        quant::Precision::F64 => {
            quant::unique::UniqueDecomp::new(&data).map(|u| u.m()).unwrap_or(0)
        }
        quant::Precision::F32 => {
            let narrow: Vec<f32> = data.iter().map(|&x| x as f32).collect();
            quant::unique::UniqueDecomp::new(&narrow).map(|u| u.m()).unwrap_or(0)
        }
    };
    let requested = opts.target_values;
    let req = quant::QuantRequest::vector(data).method(method).options(opts);
    let req = if warm { req.sweep(lambdas.clone()) } else { req.sweep_cold(lambdas.clone()) };
    let items: Vec<quant::Item> =
        quant::Quantizer::new().run(&req)?.items.into_iter().collect::<Result<_>>()?;

    println!(
        "method {} over {} λ points ({} start mode, {}), n={n} m={m}",
        method.id(),
        lambdas.len(),
        if warm { "warm" } else { "cold" },
        precision.id(),
    );
    println!(
        "{:>12} {:>9} {:>14} {:>11} {:>9} {:>9} {:>9}",
        "lambda1", "distinct", "l2_loss", "iterations", "bits/val", "idx bits", "entropy"
    );
    for (item, &lambda) in items.iter().zip(&lambdas) {
        let stats = item.compression(requested);
        println!(
            "{lambda:>12.4e} {:>9} {:>14.6e} {:>11} {:>9.3} {:>9} {:>9.3}",
            item.distinct_values(),
            item.l2_loss(),
            item.diag().iterations,
            stats.bits_per_value,
            format!("{}→{}", stats.bits_per_idx_stored, stats.bits_per_idx_packed),
            stats.index_entropy
        );
    }
    let t_prepare = items.first().map(|i| i.timings().prepare).unwrap_or_default();
    let t_solve: std::time::Duration = items.iter().map(|i| i.timings().solve).sum();
    println!("prepare time      : {t_prepare:?} (once, amortized over the grid)");
    println!("solve time        : {t_solve:?} ({} solves)", items.len());
    if let Some(form) = output {
        // Machine-readable wire format (see `jsonio` / README "Wire
        // format"), one JSON object per λ.
        for (item, &lambda) in items.iter().zip(&lambdas) {
            let json = match form {
                "codebook" => {
                    let extra = vec![
                        ("lambda", Json::Num(lambda)),
                        ("stats", jsonio::stats_to_json(&item.compression(requested))),
                    ];
                    jsonio::codebook_to_json(&item.codebook_f64(), extra)
                }
                _ => jsonio::values_to_json(
                    &item.materialize_f64(),
                    vec![("lambda", Json::Num(lambda))],
                ),
            };
            println!("{}", json.to_string());
        }
    }
    Ok(())
}

/// Quantized-compute demo: cascade build → error-vs-bits table → packed
/// matvec vs decode-then-dense cross-check → compression summary.
fn cmd_matvec(args: &Args) -> Result<()> {
    use crate::data::rng::Pcg32;
    use crate::linalg::matrix::Matrix;
    use crate::quant::tensor::Grouping;
    use crate::quant::QMatrix;

    let rows = args.flag_usize("rows", 64)?;
    let cols = args.flag_usize("cols", 32)?;
    let grouping = match args.flag("grouping").unwrap_or("per_column") {
        "per_tensor" => Grouping::PerTensor,
        "per_row" => Grouping::PerRow,
        "per_column" => Grouping::PerColumn,
        other => {
            return Err(Error::Config(format!(
                "--grouping wants per_tensor|per_row|per_column, got '{other}'"
            )))
        }
    };
    let method_id = args.flag("method").unwrap_or("kmeans");
    let method = QuantMethod::from_id(method_id)
        .ok_or_else(|| Error::Config(format!("unknown method '{method_id}'")))?;
    let bits: Vec<u32> = args
        .flag("bits")
        .unwrap_or("4,2,2")
        .split(',')
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|_| Error::Config(format!("--bits: bad width '{t}'")))
        })
        .collect::<Result<_>>()?;
    let norm_tol = args.flag_f64("norm-tol", 0.0)?;
    let seed = args.flag_usize("seed", 0)? as u64;

    // Synthetic NN-like weights: clustered values + noise, the workload
    // the paper quantizes.
    let mut rng = Pcg32::new(seed, 77);
    let m = Matrix::from_fn(rows, cols, |_, _| {
        let c = [-0.6, -0.2, 0.1, 0.45, 0.8][(rng.next_u32() % 5) as usize];
        c + rng.normal() * 0.03
    });
    let opts = QuantOptions { seed, ..Default::default() };

    let t0 = std::time::Instant::now();
    let (qm, trace) =
        QMatrix::residual_levels_traced(&m, grouping, method, &opts, &bits, norm_tol)?;
    let t_build = t0.elapsed();

    println!("matrix            : {rows}×{cols}, {method_id}, {:?}", grouping);
    println!("cascade           : --bits {:?}, --norm-tol {norm_tol:e}", bits);
    println!("{:>6} {:>6} {:>10} {:>14}", "level", "bits", "cum bits", "rel error");
    for (l, lv) in trace.iter().enumerate() {
        println!("{l:>6} {:>6} {:>10} {:>14.6e}", lv.bits, lv.cum_bits, lv.rel_error);
    }
    if trace.len() < bits.len() {
        println!(
            "(stopped after {} of {} levels: norm tolerance reached)",
            trace.len(),
            bits.len()
        );
    }

    // Cross-check the packed path against decode-then-dense on a
    // deterministic probe vector (bitwise-equal on a single level; for a
    // cascade the reference is the per-level sum, so report max |Δ|).
    let x: Vec<f64> = (0..rows).map(|i| ((i as f64) * 0.37).sin()).collect();
    let t1 = std::time::Instant::now();
    let y = qm.matvec(&x);
    let t_packed = t1.elapsed();
    let t2 = std::time::Instant::now();
    let dense = qm.decode();
    let y_ref = Matrix::from_vec(1, rows, x)?.matmul(&dense)?;
    let t_dense = t2.elapsed();
    let max_diff = y
        .iter()
        .zip(y_ref.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("build time        : {t_build:?}");
    println!("packed matvec     : {t_packed:?}");
    println!("decode+dense      : {t_dense:?} (reference)");
    println!("max |Δ| vs dense  : {max_diff:.3e}");

    let stats = qm.stats();
    println!(
        "bits/value        : {:.3} (idx {}→{} bits stored→packed, {} level planes)",
        stats.bits_per_value,
        stats.bits_per_idx_stored,
        stats.bits_per_idx_packed,
        qm.num_levels()
    );
    println!(
        "compact vs dense  : {} B vs {} B ({:.2}x)",
        stats.compact_bytes, stats.dense_bytes, stats.byte_ratio
    );
    match args.flag("output") {
        Some("json") => {
            let extra = vec![
                ("method", Json::Str(method_id.into())),
                ("stats", jsonio::stats_to_json(&stats)),
            ];
            println!("{}", jsonio::qmatrix_to_json(&qm, extra).to_string());
        }
        Some(path) => {
            let extra = vec![("method", Json::Str(method_id.into()))];
            std::fs::write(path, jsonio::qmatrix_to_json(&qm, extra).to_pretty())?;
            println!("wrote             : {path}");
        }
        None => {}
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cache = args.flag("cache").map(PathBuf::from);
    let nn = workloads::nn_workload(cache.as_deref())?;
    println!("architecture      : 784-256-128-64-10 ({} params)", nn.mlp.param_count());
    println!("train accuracy    : {:.4}", nn.train_acc);
    println!("test accuracy     : {:.4}", nn.test_acc);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let report_dir = PathBuf::from(args.flag("report-dir").unwrap_or("reports"));
    let needs_nn = matches!(which, "fig1" | "fig2" | "fig3" | "fig4" | "bitwidth" | "all");
    let nn = if needs_nn { Some(workloads::nn_workload(None)?) } else { None };

    let run = |name: &str| -> Result<()> {
        let rep = match name {
            "fig1" => figures::fig1(nn.as_ref().unwrap())?,
            "fig2" => figures::fig2(nn.as_ref().unwrap())?,
            "fig3" => figures::fig3(nn.as_ref().unwrap())?,
            "fig4" => figures::fig4(nn.as_ref().unwrap())?,
            "fig5" => figures::fig5(Some(&report_dir))?,
            "fig6" => figures::fig6()?,
            "fig7" => figures::fig7()?,
            "fig8" => figures::fig8()?,
            "crossover" => figures::crossover()?,
            "ablations" => figures::ablations()?,
            "bitwidth" => figures::bitwidth(nn.as_ref().unwrap())?,
            "oor" => figures::out_of_range()?,
            other => return Err(Error::Config(format!("unknown experiment '{other}'"))),
        };
        rep.print();
        rep.write(&report_dir, name)?;
        println!("\n[written to {}/{name}.txt + CSVs]", report_dir.display());
        Ok(())
    };

    if which == "all" {
        for name in [
            "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "crossover",
            "ablations", "bitwidth", "oor",
        ] {
            run(name)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args.flag_usize("jobs", 200)?;
    let engine = Engine::parse(args.flag("engine").unwrap_or("auto"))?;
    let precision = parse_precision(args)?;
    let defaults = Config::default();
    let cache_bytes = args.flag_usize("cache-bytes", defaults.cache_capacity_bytes)?;
    if cache_bytes == 0 {
        return Err(Error::Config(
            "--cache-bytes must be ≥ 1 (use --cache off to disable caching)".into(),
        ));
    }
    let cfg = Config {
        workers: args.flag_usize("workers", defaults.workers)?,
        engine,
        artifacts_dir: PathBuf::from(args.flag("artifacts").unwrap_or("artifacts")),
        runtime_backend: BackendKind::parse(
            args.flag("runtime-backend").unwrap_or(defaults.runtime_backend.id()),
        )?,
        runtime_fanout: args.flag_usize("runtime-fanout", defaults.runtime_fanout)?.max(1),
        runtime_lanes: args.flag_usize("lanes", defaults.runtime_lanes)?.max(1),
        cache_policy: CachePolicy::parse(args.flag("cache").unwrap_or(defaults.cache_policy.id()))?,
        cache_capacity_bytes: cache_bytes,
        ..defaults
    };
    println!(
        "starting coordinator: {} workers, engine {:?}, {} payloads, \
         runtime backend {} (lanes {}, fanout {}), cache {} ({} B)",
        cfg.workers,
        cfg.engine,
        precision.id(),
        cfg.runtime_backend.id(),
        cfg.runtime_lanes,
        cfg.runtime_fanout,
        cfg.cache_policy.id(),
        cfg.cache_capacity_bytes
    );
    let coord = Coordinator::start(cfg)?;

    // Synthetic job mix: three data shapes × four methods, drawn from a
    // pool of `--distinct` units and cycled across the submits. With
    // --jobs > --distinct the traffic is repeat-heavy: every lap after
    // the first is answered by the serve-path result cache (when on),
    // and the metrics summary reports the hit rate.
    let mut rng = crate::data::rng::Pcg32::seeded(args.flag_usize("seed", 1)? as u64);
    let distinct = args.flag_usize("distinct", 24)?.max(1).min(jobs.max(1));
    let pool: Vec<(Vec<f64>, QuantMethod, QuantOptions)> = (0..distinct)
        .map(|i| {
            let n = [64usize, 256, 640][i % 3];
            let data: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
            let method = [
                QuantMethod::L1LeastSquare,
                QuantMethod::KMeans,
                QuantMethod::ClusterLs,
                QuantMethod::L1,
            ][i % 4];
            let opts = QuantOptions {
                lambda1: 0.01,
                target_values: 16,
                seed: i as u64,
                ..Default::default()
            };
            (data, method, opts)
        })
        .collect();
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let (data, method, opts) = &pool[i % pool.len()];
        let (_, rx) = match precision {
            quant::Precision::F64 => coord.submit(data.clone(), *method, opts.clone())?,
            quant::Precision::F32 => {
                // f32 clients submit typed payloads; no up-front widening.
                let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                coord.submit_f32(data32, *method, opts.clone())?
            }
        };
        rxs.push(rx);
    }
    let mut ok = 0usize;
    let mut stats: Vec<CompressionStats> = Vec::new();
    for rx in rxs {
        let res = rx.recv().map_err(|_| Error::Coordinator("worker dropped job".into()))?;
        if let Ok(out) = &res.outcome {
            ok += 1;
            // Results come back compact; the accounting is a cheap read
            // off the codebook the worker already built.
            stats.push(out.compression());
        }
    }
    let wall = t0.elapsed();
    let snap = coord.shutdown();
    println!("jobs              : {jobs} submitted, {ok} ok");
    println!("wall time         : {wall:?}");
    println!(
        "throughput        : {:.1} jobs/s",
        jobs as f64 / wall.as_secs_f64()
    );
    if let Some(agg) = CompressionStats::aggregate(stats.iter()) {
        println!("compression       : {}", agg.summary());
    }
    println!("metrics           : {}", snap.summary());
    Ok(())
}

fn cmd_listen(args: &Args) -> Result<()> {
    use std::io::Write as _;
    let engine = Engine::parse(args.flag("engine").unwrap_or("native"))?;
    let defaults = Config::default();
    let cache_bytes = args.flag_usize("cache-bytes", defaults.cache_capacity_bytes)?;
    if cache_bytes == 0 {
        return Err(Error::Config(
            "--cache-bytes must be ≥ 1 (use --cache off to disable caching)".into(),
        ));
    }
    let cache_shared = match args.flag("cache-shared") {
        None => defaults.cache_shared,
        Some("true") => true,
        Some("false") => false,
        Some(v) => {
            return Err(Error::Config(format!(
                "--cache-shared wants true|false, got '{v}'"
            )))
        }
    };
    let cfg = Config {
        workers: args.flag_usize("workers", defaults.workers)?,
        engine,
        queue_capacity: args.flag_usize("queue-capacity", defaults.queue_capacity)?,
        cache_policy: CachePolicy::parse(args.flag("cache").unwrap_or(defaults.cache_policy.id()))?,
        cache_capacity_bytes: cache_bytes,
        cache_shared,
        ..defaults
    };
    let scfg = crate::serve::ServeConfig {
        addr: args.flag("addr").unwrap_or("127.0.0.1:7878").to_string(),
        max_conns: args.flag_usize("max-conns", 64)?.max(1),
        tenant_rate: args.flag_f64("tenant-rate", 0.0)?,
        tenant_burst: args.flag_f64("tenant-burst", 8.0)?,
        shed_retry_ms: args.flag_usize("shed-retry-ms", 50)? as u64,
    };
    let duration_secs = args.flag_f64("duration-secs", 0.0)?;
    let coord = Coordinator::start(cfg)?;
    let server = crate::serve::Server::start(coord, scfg)?;
    // The smoke job greps this line for the bound address, so flush it
    // through any pipe buffering before we start (possibly) sleeping.
    println!("listening on {}", server.addr());
    std::io::stdout().flush().ok();
    if duration_secs > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(duration_secs));
        let snap = server.shutdown();
        println!("drained: {}", snap.summary());
        println!("{}", snap.to_json().to_string());
        Ok(())
    } else {
        // No in-process signal handling (std-only): run until killed.
        loop {
            std::thread::sleep(std::time::Duration::from_secs(1));
        }
    }
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let defaults = crate::serve::LoadSpec::default();
    let spec = crate::serve::LoadSpec {
        addr: args.flag("addr").unwrap_or(&defaults.addr).to_string(),
        jobs: args.flag_usize("jobs", defaults.jobs)?,
        conns: args.flag_usize("conns", defaults.conns)?,
        tenants: args.flag_usize("tenants", defaults.tenants)?,
        codec: crate::serve::Codec::parse(args.flag("codec").unwrap_or(defaults.codec.id()))?,
        distinct: args.flag_usize("distinct", defaults.distinct)?,
        n: args.flag_usize("n", defaults.n)?,
        seed: args.flag_usize("seed", defaults.seed as usize)? as u64,
    };
    let report = crate::serve::run_load(&spec)?;
    println!("loadgen: {}", report.summary());
    for (tenant, done) in &report.per_tenant_completed {
        println!("  {tenant}: {done} completed");
    }
    println!("{}", report.to_json().to_string());
    if report.completed == 0 {
        return Err(Error::Runtime(
            "loadgen: zero jobs completed (all shed or failed)".into(),
        ));
    }
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    check_artifacts(&dir)
}

/// Self-check used by the CLI and smoke tests: every artifact loads,
/// compiles, and the runtime agrees with the native engines.
pub fn check_artifacts(dir: &Path) -> Result<()> {
    use crate::coordinator::router::check_lasso_equivalence;
    let mut ex = crate::runtime::Executor::open(dir)?;
    println!("platform          : {}", ex.platform());
    println!("max lasso bucket  : m={}", ex.max_lasso_m());
    let mut rng = crate::data::rng::Pcg32::seeded(17);
    let data: Vec<f64> = (0..300).map(|_| rng.uniform(0.0, 1.0)).collect();
    let (native, runtime) = check_lasso_equivalence(&mut ex, &data, 0.01)?;
    let rel = (native - runtime).abs() / native.abs().max(1e-12);
    println!("lasso loss        : native {native:.6e} vs runtime {runtime:.6e} (rel {rel:.2e})");
    if rel > 0.20 {
        return Err(Error::Runtime(format!(
            "runtime/native divergence too large: {rel:.3}"
        )));
    }
    println!("selfcheck OK");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_command_and_flags() {
        let a = parse_args(&s(&["eval", "fig7", "--report-dir", "/tmp/r", "--quick"])).unwrap();
        assert_eq!(a.command, "eval");
        assert_eq!(a.positionals, vec!["fig7"]);
        assert_eq!(a.flag("report-dir"), Some("/tmp/r"));
        assert_eq!(a.flag("quick"), Some("true"));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&s(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_and_version_run() {
        dispatch(&s(&[])).unwrap();
        dispatch(&s(&["help"])).unwrap();
        dispatch(&s(&["version"])).unwrap();
    }

    #[test]
    fn quantize_demo_runs() {
        dispatch(&s(&["quantize", "--method", "kmeans", "--values", "8", "--clamp", "0,1"]))
            .unwrap();
    }

    #[test]
    fn quantize_rejects_bad_method() {
        assert!(dispatch(&s(&["quantize", "--method", "nope"])).is_err());
    }

    #[test]
    fn quantize_with_weights_and_entropy_budget_runs() {
        let dir = std::env::temp_dir().join("sqlsq_cli_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("in.txt");
        let wfile = dir.join("w.txt");
        let data: Vec<String> = (0..32).map(|i| format!("{:.3}", (i % 5) as f64 * 0.2)).collect();
        std::fs::write(&input, data.join("\n")).unwrap();
        let wts: Vec<String> = (0..32).map(|i| format!("{:.3}", 0.5 + (i % 3) as f64)).collect();
        std::fs::write(&wfile, wts.join("\n")).unwrap();
        dispatch(&s(&[
            "quantize", "--method", "kmeans", "--values", "4", "--input",
            input.to_str().unwrap(), "--weights", wfile.to_str().unwrap(),
        ]))
        .unwrap();
        dispatch(&s(&[
            "quantize", "--method", "kmeans", "--values", "8", "--input",
            input.to_str().unwrap(), "--entropy-budget", "1.0", "--output", "codebook",
        ]))
        .unwrap();
        // Length mismatch / malformed budget are input errors, not panics.
        std::fs::write(&wfile, "1.0 2.0").unwrap();
        assert!(dispatch(&s(&[
            "quantize", "--method", "kmeans", "--input", input.to_str().unwrap(),
            "--weights", wfile.to_str().unwrap(),
        ]))
        .is_err());
        assert!(dispatch(&s(&["quantize", "--entropy-budget", "nope"])).is_err());
        assert!(dispatch(&s(&["quantize", "--entropy-budget", "-1"])).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sweep_demo_runs_warm_and_cold() {
        dispatch(&s(&["sweep", "--method", "l1_ls", "--steps", "4"])).unwrap();
        dispatch(&s(&[
            "sweep", "--method", "l1", "--steps", "3", "--cold", "--lambda-min", "1e-3",
            "--lambda-max", "1e-1",
        ]))
        .unwrap();
    }

    #[test]
    fn sweep_rejects_bad_grid() {
        assert!(dispatch(&s(&["sweep", "--method", "l1", "--steps", "0"])).is_err());
        assert!(dispatch(&s(&["sweep", "--method", "nope"])).is_err());
    }

    #[test]
    fn f32_precision_lane_runs_quantize_and_sweep() {
        dispatch(&s(&[
            "quantize", "--method", "l1_ls", "--values", "8", "--precision", "f32",
        ]))
        .unwrap();
        dispatch(&s(&["sweep", "--method", "l1_ls", "--steps", "3", "--precision", "f32"]))
            .unwrap();
        assert!(dispatch(&s(&["quantize", "--precision", "f16"])).is_err());
    }

    #[test]
    fn serve_small_f32_native_run() {
        dispatch(&s(&[
            "serve", "--jobs", "8", "--engine", "native", "--workers", "2", "--precision", "f32",
        ]))
        .unwrap();
    }

    #[test]
    fn quantize_compact_output_forms_run() {
        dispatch(&s(&[
            "quantize", "--method", "kmeans", "--values", "4", "--output", "codebook",
        ]))
        .unwrap();
        dispatch(&s(&[
            "quantize", "--method", "kmeans", "--values", "4", "--output", "values",
        ]))
        .unwrap();
    }

    #[test]
    fn sweep_compact_output_forms_run() {
        dispatch(&s(&["sweep", "--method", "l1_ls", "--steps", "3", "--output", "codebook"]))
            .unwrap();
        dispatch(&s(&["sweep", "--method", "l1", "--steps", "3", "--output", "values"]))
            .unwrap();
        assert!(dispatch(&s(&[
            "sweep", "--method", "l1", "--steps", "3", "--output", "bogus",
        ]))
        .is_err());
    }

    #[test]
    fn matvec_demo_runs_and_writes_qmatrix_wire() {
        dispatch(&s(&["matvec", "--rows", "16", "--cols", "8", "--bits", "3,2"])).unwrap();
        dispatch(&s(&[
            "matvec", "--rows", "12", "--cols", "6", "--grouping", "per_tensor", "--bits", "2",
            "--output", "json",
        ]))
        .unwrap();
        let dir = std::env::temp_dir().join("sqlsq_cli_matvec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("qm.json");
        dispatch(&s(&[
            "matvec", "--rows", "10", "--cols", "5", "--bits", "2,1", "--norm-tol", "1e-6",
            "--output", out.to_str().unwrap(),
        ]))
        .unwrap();
        let wire = std::fs::read_to_string(&out).unwrap();
        let qm = jsonio::qmatrix_from_json(&jsonio::parse(&wire).unwrap()).unwrap();
        assert_eq!((qm.rows(), qm.cols()), (10, 5));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn matvec_rejects_bad_flags() {
        assert!(dispatch(&s(&["matvec", "--grouping", "per_banana"])).is_err());
        assert!(dispatch(&s(&["matvec", "--bits", "0"])).is_err());
        assert!(dispatch(&s(&["matvec", "--bits", "x"])).is_err());
        assert!(dispatch(&s(&["matvec", "--method", "nope"])).is_err());
    }

    #[test]
    fn quantize_from_file() {
        let dir = std::env::temp_dir().join("sqlsq_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("in.txt");
        std::fs::write(&input, "# data\n1.0, 1.1\n5.0 5.1\n9.0\n").unwrap();
        let out = dir.join("out.txt");
        dispatch(&s(&[
            "quantize",
            "--method",
            "cluster_ls",
            "--values",
            "3",
            "--input",
            input.to_str().unwrap(),
            "--output",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(out).unwrap();
        assert_eq!(text.lines().count(), 5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn eval_fig7_writes_report() {
        let dir = std::env::temp_dir().join("sqlsq_cli_eval_test");
        dispatch(&s(&["eval", "fig7", "--report-dir", dir.to_str().unwrap()])).unwrap();
        assert!(dir.join("fig7.txt").exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn serve_small_native_run() {
        dispatch(&s(&["serve", "--jobs", "12", "--engine", "native", "--workers", "2"])).unwrap();
    }

    #[test]
    fn serve_repeat_heavy_traffic_runs_with_cache_on_and_off() {
        dispatch(&s(&[
            "serve", "--jobs", "12", "--distinct", "4", "--engine", "native", "--workers", "2",
        ]))
        .unwrap();
        dispatch(&s(&[
            "serve", "--jobs", "8", "--distinct", "4", "--engine", "native", "--workers", "2",
            "--cache", "off", "--cache-bytes", "4096",
        ]))
        .unwrap();
        assert!(dispatch(&s(&["serve", "--cache", "fifo"])).is_err());
        assert!(dispatch(&s(&["serve", "--cache-bytes", "0"])).is_err());
    }

    #[test]
    fn listen_binds_serves_for_a_beat_and_drains() {
        dispatch(&s(&[
            "listen", "--addr", "127.0.0.1:0", "--workers", "2", "--engine", "native",
            "--duration-secs", "0.2",
        ]))
        .unwrap();
    }

    #[test]
    fn listen_rejects_bad_flags() {
        assert!(dispatch(&s(&["listen", "--addr", "not-an-addr", "--duration-secs", "0.1"]))
            .is_err());
        assert!(dispatch(&s(&["listen", "--cache-shared", "maybe"])).is_err());
        assert!(dispatch(&s(&["listen", "--cache-bytes", "0"])).is_err());
    }

    #[test]
    fn loadgen_rejects_bad_flags_and_dead_servers() {
        assert!(dispatch(&s(&["loadgen", "--codec", "xml"])).is_err());
        // A port nothing listens on: total transport failure is an error.
        assert!(dispatch(&s(&[
            "loadgen", "--addr", "127.0.0.1:9", "--jobs", "2", "--conns", "1",
        ]))
        .is_err());
    }

    #[test]
    fn loadgen_completes_against_a_live_listener() {
        let cfg = Config {
            workers: 2,
            engine: Engine::parse("native").unwrap(),
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        let server = crate::serve::Server::start(
            coord,
            crate::serve::ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
        )
        .unwrap();
        let addr = server.addr().to_string();
        dispatch(&s(&[
            "loadgen", "--addr", &addr, "--jobs", "8", "--conns", "2", "--tenants", "2",
            "--codec", "json", "--n", "64",
        ]))
        .unwrap();
        let snap = server.shutdown();
        assert!(snap.completed >= 8, "all offered jobs completed: {}", snap.summary());
    }

    #[test]
    fn serve_auto_with_shadow_backend_runs_without_artifacts() {
        dispatch(&s(&[
            "serve", "--jobs", "12", "--engine", "auto", "--workers", "2", "--lanes", "1",
            "--runtime-backend", "shadow", "--runtime-fanout", "2",
        ]))
        .unwrap();
        assert!(dispatch(&s(&["serve", "--runtime-backend", "tpu"])).is_err());
    }
}
