//! Shared experiment workloads (§4): the trained MLP, the digit image,
//! and the three synthetic datasets. Heavyweight artifacts (the trained
//! network) are cached on disk so figure harnesses don't retrain.

use crate::data::distributions::{sample, SynthKind, SynthParams};
use crate::data::rng::Pcg32;
use crate::data::synth_digits::{self, DigitDataset};
use crate::nn::mlp::Mlp;
use crate::nn::train::{self, TrainConfig};
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Sizes for the §4.1 corpus. Chosen so training takes ~tens of seconds
/// while leaving the accuracy-vs-quantization curves well-resolved.
pub const TRAIN_N: usize = 2000;
/// Held-out set size.
pub const TEST_N: usize = 500;
/// Seed for the corpus (train and test use different streams).
pub const CORPUS_SEED: u64 = 20180724;

/// Everything the NN experiments need.
pub struct NnWorkload {
    /// The trained 784-256-128-64-10 network.
    pub mlp: Mlp,
    /// Training set.
    pub train: DigitDataset,
    /// Held-out set.
    pub test: DigitDataset,
    /// Baseline train accuracy (unquantized).
    pub train_acc: f64,
    /// Baseline test accuracy (unquantized).
    pub test_acc: f64,
}

/// Default weight-cache location (gitignored, next to artifacts).
pub fn default_cache() -> PathBuf {
    PathBuf::from("artifacts").join("cache").join("mlp_weights.txt")
}

/// Load-or-train the paper's MLP. The corpus is regenerated (cheap,
/// deterministic); only the weights are cached.
pub fn nn_workload(cache: Option<&Path>) -> Result<NnWorkload> {
    let train_ds = synth_digits::generate(TRAIN_N, CORPUS_SEED);
    let test_ds = synth_digits::generate(TEST_N, CORPUS_SEED + 1);

    let cache_path = cache.map(Path::to_path_buf).unwrap_or_else(default_cache);
    let mlp = match train::load_weights(&cache_path) {
        Ok(m) if m.in_dim() == 784 && m.out_dim() == 10 => m,
        _ => {
            eprintln!("training MLP ({} images, arch 784-256-128-64-10)...", TRAIN_N);
            let mut m = Mlp::paper_arch(7);
            let cfg = TrainConfig {
                epochs: 14,
                lr: 0.08,
                momentum: 0.9,
                batch: 64,
                seed: 1,
                log_every: 0,
            };
            let report = train::train(&mut m, &train_ds, &cfg)?;
            eprintln!(
                "trained: final loss {:.4}, train acc {:.4}",
                report.final_loss, report.train_accuracy
            );
            train::save_weights(&m, &cache_path)?;
            m
        }
    };
    let train_acc = train::evaluate(&mlp, &train_ds)?;
    let test_acc = train::evaluate(&mlp, &test_ds)?;
    Ok(NnWorkload { mlp, train: train_ds, test: test_ds, train_acc, test_acc })
}

/// Accuracy of `mlp` with one layer's weights replaced by `quantized`.
/// Restores nothing — callers pass a clone or re-set afterwards.
pub fn accuracy_with_layer(
    mlp: &Mlp,
    layer: usize,
    quantized: &[f64],
    train_ds: &DigitDataset,
    test_ds: &DigitDataset,
) -> Result<(f64, f64)> {
    let mut m = mlp.clone();
    m.set_layer_weights(layer, quantized)?;
    Ok((train::evaluate(&m, train_ds)?, train::evaluate(&m, test_ds)?))
}

/// The §4.2 image workload: a canonical rendered digit in `[0,1]`.
pub fn digit_image() -> Vec<f64> {
    synth_digits::canonical_digit(5).pixels
}

/// Log-spaced λ grid for sweep workloads (CLI `sweep`, the batch-sweep
/// bench, figure harnesses): `n` points from `min` to `max` inclusive.
pub fn lambda_grid(min: f64, max: f64, n: usize) -> Result<Vec<f64>> {
    if min <= 0.0 || !min.is_finite() || !max.is_finite() {
        return Err(Error::InvalidParam(format!(
            "lambda_grid: bounds must be finite and positive (min={min}, max={max})"
        )));
    }
    if max < min {
        return Err(Error::InvalidParam(format!(
            "lambda_grid: max {max} < min {min}"
        )));
    }
    if n == 0 {
        return Err(Error::InvalidParam("lambda_grid: n must be ≥ 1".into()));
    }
    if n == 1 {
        return Ok(vec![min]);
    }
    let ratio = (max / min).powf(1.0 / (n - 1) as f64);
    let mut grid = Vec::with_capacity(n);
    let mut lambda = min;
    for _ in 0..n {
        grid.push(lambda);
        lambda *= ratio;
    }
    Ok(grid)
}

/// The §4.3 synthetic datasets (500 samples each in [0, 100]).
pub fn synth_datasets(seed: u64) -> Vec<(SynthKind, Vec<f64>)> {
    let params = SynthParams::default();
    SynthKind::ALL
        .iter()
        .map(|&kind| {
            let mut rng = Pcg32::new(seed, kind as u64 + 1);
            (kind, sample(kind, &params, &mut rng))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_image_in_unit_range() {
        let img = digit_image();
        assert_eq!(img.len(), 784);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(img.iter().any(|&v| v > 0.5));
    }

    #[test]
    fn lambda_grid_is_log_spaced_and_inclusive() {
        let g = lambda_grid(1e-4, 1e-1, 16).unwrap();
        assert_eq!(g.len(), 16);
        assert!((g[0] - 1e-4).abs() < 1e-12);
        assert!((g[15] - 1e-1).abs() < 1e-6);
        for pair in g.windows(2) {
            assert!(pair[1] > pair[0]);
        }
        // Constant ratio between neighbours (log spacing).
        let r0 = g[1] / g[0];
        for pair in g.windows(2) {
            assert!((pair[1] / pair[0] - r0).abs() < 1e-9);
        }
        assert_eq!(lambda_grid(1e-3, 1e-3, 1).unwrap(), vec![1e-3]);
        assert!(lambda_grid(0.0, 1.0, 4).is_err());
        assert!(lambda_grid(1.0, 0.5, 4).is_err());
        assert!(lambda_grid(1e-3, 1e-1, 0).is_err());
    }

    #[test]
    fn synth_datasets_deterministic() {
        let a = synth_datasets(1);
        let b = synth_datasets(1);
        assert_eq!(a.len(), 3);
        for ((ka, va), (kb, vb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(va, vb);
            assert_eq!(va.len(), 500);
        }
    }

    #[test]
    fn accuracy_with_layer_swaps_cleanly() {
        // Tiny net to keep the test fast; semantic check only.
        let ds = synth_digits::generate(60, 3);
        let mut mlp = Mlp::new(&[784, 16, 10], 1);
        train::train(&mut mlp, &ds, &TrainConfig { epochs: 2, ..Default::default() }).unwrap();
        let w = mlp.layer_weights(1).to_vec();
        let (tr, te) = accuracy_with_layer(&mlp, 1, &w, &ds, &ds).unwrap();
        // Identity replacement must not change accuracy.
        let base = train::evaluate(&mlp, &ds).unwrap();
        assert!((tr - base).abs() < 1e-12);
        assert!((te - base).abs() < 1e-12);
        // Zeroing the layer wrecks it.
        let zeros = vec![0.0; w.len()];
        let (trz, _) = accuracy_with_layer(&mlp, 1, &zeros, &ds, &ds).unwrap();
        assert!(trz <= base);
    }
}
