//! Evaluation harness (S20): workloads, figure regeneration, and report
//! plumbing for every experiment in DESIGN §5.
//!
//! Three submodules, one per concern:
//!
//! * [`workloads`] — the shared experiment substrates: the trained
//!   784-256-128-64-10 MLP (cached on disk so harnesses don't retrain),
//!   the procedural digit image, the paper's three synthetic
//!   distributions, and the λ-grid helper the sweep surfaces share.
//! * [`figures`] — one function per experiment (Fig 1–8, crossover,
//!   ablations, bit-width, out-of-range), each returning a
//!   [`report::Report`]. Absolute numbers differ from the paper's 2018
//!   testbed; orderings, curve shapes and crossovers are the
//!   reproduction targets (EXPERIMENTS.md has the side-by-side).
//! * [`report`] — the rendering layer: aligned text tables + CSV twins,
//!   including the standard compression-accounting columns
//!   ([`report::Table::compression`]) shared with the CLI so
//!   bits-per-value numbers are comparable across surfaces.
//!
//! Everything here consumes the public `quant` API only (no coordinator
//! required), so `sqlsq eval <exp>` runs offline on a bare checkout.

pub mod figures;
pub mod report;
pub mod workloads;
