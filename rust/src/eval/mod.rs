//! Evaluation harness (S20): workloads, figure regeneration, and report
//! plumbing for every experiment in DESIGN §5.

pub mod figures;
pub mod report;
pub mod workloads;
