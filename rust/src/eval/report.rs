//! Experiment report plumbing: aligned text tables + CSV files.
//!
//! Every figure/experiment harness ([`super::figures`]) renders through
//! the same two types — [`Table`] (aligned text + CSV twin, one file per
//! table) and [`Report`] (prose sections interleaved with tables,
//! persisted as `<dir>/<name>.txt` plus per-table CSVs) — so results are
//! both human-readable on stdout and machine-consumable for plotting.
//!
//! The compression-accounting columns ([`Table::compression`] /
//! [`Table::compression_row`]) are the standard rendering of
//! [`CompressionStats`] wherever bits-per-value results are reported (the
//! CLI's quantize/serve summaries and the bit-width experiments share
//! them, so numbers stay comparable across surfaces).

use crate::quant::CompressionStats;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// A labeled table of results.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (also the CSV file slug).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (panics on arity mismatch — a test bug, not user
    /// input).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table '{}' arity", self.title);
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("\n### {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV text.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// A table with the standard compression-accounting columns (pair
    /// with [`Table::compression_row`]).
    pub fn compression(title: &str) -> Table {
        Table::new(
            title,
            &[
                "label", "n", "levels", "requested", "idx_bits_stored/packed", "bits/val",
                "entropy", "compact_B", "dense_B", "ratio",
            ],
        )
    }

    /// Append one [`CompressionStats`] row to a [`Table::compression`]
    /// table.
    pub fn compression_row(&mut self, label: &str, s: &CompressionStats) {
        self.row(vec![
            label.to_string(),
            s.n.to_string(),
            s.levels_achieved.to_string(),
            s.levels_requested.to_string(),
            format!("{}/{}", s.bits_per_idx_stored, s.bits_per_idx_packed),
            format!("{:.3}", s.bits_per_value),
            format!("{:.3}", s.index_entropy),
            s.compact_bytes.to_string(),
            s.dense_bytes.to_string(),
            format!("{:.2}", s.byte_ratio),
        ]);
    }

    /// Write `<dir>/<slug>.csv` and return the path.
    pub fn write_csv(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// A figure report: prose + tables, printed and persisted together.
#[derive(Debug, Default)]
pub struct Report {
    sections: Vec<String>,
    tables: Vec<Table>,
}

impl Report {
    /// New empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Add a prose section.
    pub fn text(&mut self, s: impl Into<String>) {
        self.sections.push(s.into());
    }

    /// Add a table (also rendered inline at this position).
    pub fn table(&mut self, t: Table) {
        self.sections.push(t.render());
        self.tables.push(t);
    }

    /// Print to stdout.
    pub fn print(&self) {
        for s in &self.sections {
            println!("{s}");
        }
    }

    /// Persist: text to `<dir>/<name>.txt`, every table to CSV.
    pub fn write(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{name}.txt")))?;
        for s in &self.sections {
            writeln!(f, "{s}")?;
        }
        for t in &self.tables {
            t.write_csv(dir)?;
        }
        Ok(())
    }
}

/// Format a float for tables.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

/// Format a duration in seconds for tables.
pub fn secs(s: f64) -> String {
    format!("{s:.5}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["k", "loss"]);
        t.row(vec!["2".into(), "0.5".into()]);
        t.row(vec!["16".into(), "0.0125".into()]);
        let r = t.render();
        assert!(r.contains("### Demo"));
        assert!(r.contains("loss"));
        assert_eq!(r.lines().count(), 6);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("X", &["a"]);
        t.row(vec!["with,comma".into()]);
        assert!(t.to_csv().contains("\"with,comma\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("X", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn report_writes_files() {
        let dir = std::env::temp_dir().join("sqlsq_report_test");
        let mut r = Report::new();
        r.text("hello");
        let mut t = Table::new("Fig X", &["a"]);
        t.row(vec!["1".into()]);
        r.table(t);
        r.write(&dir, "fig_x").unwrap();
        assert!(dir.join("fig_x.txt").exists());
        assert!(dir.join("fig_x.csv").exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn compression_table_rows_align_with_headers() {
        use crate::quant::Codebook;
        let cb =
            Codebook::from_values(&(0..100).map(|i| (i % 4) as f64).collect::<Vec<_>>()).unwrap();
        let mut t = Table::compression("Compression");
        t.compression_row("demo", &cb.stats(4));
        let r = t.render();
        assert!(r.contains("bits/val"));
        assert!(r.contains("demo"));
        assert!(t.to_csv().lines().count() == 2);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(0.0), "0");
        assert!(f(12345.0).contains('e'));
        assert_eq!(f(0.5), "0.5000");
        assert_eq!(secs(0.123456), "0.12346");
    }
}
