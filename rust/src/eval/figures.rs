//! Figure/table regeneration (deliverable d): one function per experiment
//! in DESIGN §5's index (E1–E10). Each returns a [`Report`] that the CLI
//! prints and persists under the report dir.
//!
//! The absolute numbers differ from the paper's 2018 testbed; the
//! *orderings, curve shapes and crossovers* are the reproduction targets —
//! see EXPERIMENTS.md for the paper-vs-measured comparison.

use super::report::{f, secs, Report, Table};
use super::workloads::{self, NnWorkload};
use crate::data::distributions::histogram;
use crate::data::rng::Pcg32;
use crate::data::synth_digits;
use crate::linalg::stats;
use crate::quant::{self, QuantMethod, QuantOptions, QuantOutput};
use crate::Result;
use std::time::Instant;

/// Quantize with wall-clock measurement.
pub fn timed(data: &[f64], method: QuantMethod, opts: &QuantOptions) -> Result<(QuantOutput, f64)> {
    let t0 = Instant::now();
    let out = quant::quantize(data, method, opts)?;
    Ok((out, t0.elapsed().as_secs_f64()))
}

/// λ₁ grid used wherever the l1 family is swept against value counts.
pub fn lambda_grid() -> Vec<f64> {
    // Log-spaced 1e-4 … 2.0; dense enough to cover the count range of a
    // 640-value weight matrix.
    let mut v = Vec::new();
    let mut x = 1e-4;
    while x <= 2.0 {
        v.push(x);
        x *= 2.3;
    }
    v
}

/// Count grid for the count-taking methods (Fig 1/5/8 x-axes).
pub fn count_grid(max: usize) -> Vec<usize> {
    [2usize, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128]
        .into_iter()
        .filter(|&k| k <= max)
        .collect()
}

const FIG1_COUNT_METHODS: [QuantMethod; 4] = [
    QuantMethod::KMeans,
    QuantMethod::ClusterLs,
    QuantMethod::Gmm,
    QuantMethod::DataTransform,
];

/// E1 / Figure 1 — post-quantization accuracy + runtime vs value count on
/// the MLP last layer (64×10).
pub fn fig1(nn: &NnWorkload) -> Result<Report> {
    let mut rep = Report::new();
    rep.text(format!(
        "Figure 1 — last-layer (64x10) quantization. Baseline accuracy: train {:.4}, test {:.4}.",
        nn.train_acc, nn.test_acc
    ));
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut table = Table::new(
        "Fig1 accuracy and runtime",
        &["method", "requested", "achieved", "train_acc", "test_acc", "seconds"],
    );

    // Count-taking methods on the k grid.
    for method in FIG1_COUNT_METHODS {
        for &k in &count_grid(256) {
            let opts = QuantOptions { target_values: k, seed: 42, ..Default::default() };
            let (out, dt) = timed(&weights, method, &opts)?;
            let (tr, te) =
                workloads::accuracy_with_layer(&nn.mlp, 3, &out.values, &nn.train, &nn.test)?;
            table.row(vec![
                method.id().into(),
                k.to_string(),
                out.distinct_values().to_string(),
                f(tr),
                f(te),
                secs(dt),
            ]);
        }
    }
    // λ-swept l1 family (the paper's own protocol: the achieved count is
    // whatever the λ produces).
    for method in [QuantMethod::L1, QuantMethod::L1LeastSquare] {
        for &lambda in &lambda_grid() {
            let opts = QuantOptions { lambda1: lambda, seed: 42, ..Default::default() };
            let (out, dt) = timed(&weights, method, &opts)?;
            let (tr, te) =
                workloads::accuracy_with_layer(&nn.mlp, 3, &out.values, &nn.train, &nn.test)?;
            table.row(vec![
                method.id().into(),
                format!("λ={lambda:.2e}"),
                out.distinct_values().to_string(),
                f(tr),
                f(te),
                secs(dt),
            ]);
        }
    }
    rep.table(table);
    rep.text(
        "Expected shape (paper §4.1): accuracy is flat until the count gets small; \
         l1_ls ≈ kmeans ≈ cluster_ls in accuracy with cluster_ls best near the cliff; \
         gmm slightly worse; l1-family runtimes well below the kmeans family.",
    );
    Ok(rep)
}

/// E2 / Figure 2 — zoom on the accuracy cliff (small counts, step 1).
pub fn fig2(nn: &NnWorkload) -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 2 — zoom on the accuracy-drop region (k = 2..16).");
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut table = Table::new(
        "Fig2 accuracy zoom",
        &["method", "k", "achieved", "train_acc", "test_acc"],
    );
    for method in [QuantMethod::KMeans, QuantMethod::ClusterLs, QuantMethod::IterativeL1] {
        for k in 2..=16usize {
            let opts = QuantOptions {
                target_values: k,
                lambda1: 1e-3,
                seed: 42,
                ..Default::default()
            };
            let (out, _) = timed(&weights, method, &opts)?;
            let (tr, te) =
                workloads::accuracy_with_layer(&nn.mlp, 3, &out.values, &nn.train, &nn.test)?;
            table.row(vec![
                method.id().into(),
                k.to_string(),
                out.distinct_values().to_string(),
                f(tr),
                f(te),
            ]);
        }
    }
    rep.table(table);
    Ok(rep)
}

/// E3 / Figure 3 — the α-vector distributions for four solver variants.
pub fn fig3(nn: &NnWorkload) -> Result<Report> {
    use crate::quant::{lasso, refit, unique::UniqueDecomp, vmatrix::VBasis};
    let mut rep = Report::new();
    rep.text(
        "Figure 3 — α distributions on the last-layer weights: least square without \
         sparsity, l1 without LS, l1 with LS, and the cluster-LS equivalent dense form.",
    );
    let weights = nn.mlp.layer_weights(3).to_vec();
    let u = UniqueDecomp::new(&weights)?;
    let basis = VBasis::new(&u.values);
    let m = u.m();

    // (a) LS with the full support — exactly 𝟙 (paper's left plot).
    let full: Vec<usize> = (0..m).collect();
    let ls_alpha = refit::refit_fast(&basis, &u.values, &full, None)?.alpha;

    // (b)/(c) l1 at a λ that lands in the hundreds of values.
    let cfg = lasso::LassoConfig { lambda1: 5e-3, ..Default::default() };
    let sol = lasso::solve(&basis, &u.values, &cfg, None)?;
    let l1_alpha = sol.alpha.clone();
    let l1ls_alpha = refit::refit_fast(&basis, &u.values, &sol.support(), None)?.alpha;

    // (d) cluster-LS: dense equivalent — level deltas placed at segment
    // starts (the paper's "starting index of each batch" trick).
    let cls = crate::quant::cluster_ls::solve_cluster_ls(
        &basis,
        &u.values,
        Some(&u.weights()),
        &crate::quant::cluster_ls::ClusterLsConfig { l: sol.nnz().max(2), ..Default::default() },
    )?;
    let mut cls_alpha = vec![0.0; m];
    let mut prev = 0.0;
    for (c, &start) in cls.boundaries.iter().enumerate() {
        let d = basis.diffs()[start];
        if d != 0.0 {
            cls_alpha[start] = (cls.levels[c] - prev) / d;
        }
        prev = cls.levels[c];
    }

    let mut table = Table::new(
        "Fig3 alpha vectors",
        &["index", "ls_full", "l1", "l1_ls", "cluster_ls"],
    );
    for i in 0..m {
        table.row(vec![
            i.to_string(),
            f(ls_alpha[i]),
            f(l1_alpha[i]),
            f(l1ls_alpha[i]),
            f(cls_alpha[i]),
        ]);
    }
    rep.table(table);

    // Summary stats the paper narrates: positivity and the central zero
    // region.
    let pos = l1_alpha.iter().filter(|&&a| a > 0.0).count();
    let neg = l1_alpha.iter().filter(|&&a| a < 0.0).count();
    rep.text(format!(
        "l1 α signs: {pos} positive vs {neg} negative (paper: almost all positive — \
         consistent with shrinkage + the V configuration). nnz={} of m={}.",
        sol.nnz(),
        m
    ));
    Ok(rep)
}

/// E4 / Figure 4 — l1 vs l1+negative-l2 across λ₁ (λ₂ = 4e-3·λ₁).
pub fn fig4(nn: &NnWorkload) -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 4 — sole l1 vs l1+(negative)l2, λ2 = 4e-3·λ1, no LS refit (paper setup).");
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut table = Table::new(
        "Fig4 l1 vs l1+l2",
        &["lambda1", "variant", "achieved", "l2_loss", "train_acc", "test_acc"],
    );
    for &lambda in &lambda_grid() {
        for (variant, lambda2) in [("l1", 0.0), ("l1_l2", 4e-3 * lambda)] {
            let opts = QuantOptions {
                lambda1: lambda,
                lambda2,
                refit: false,
                seed: 42,
                ..Default::default()
            };
            let (out, _) = timed(&weights, QuantMethod::L1L2, &opts)?;
            let (tr, te) =
                workloads::accuracy_with_layer(&nn.mlp, 3, &out.values, &nn.train, &nn.test)?;
            table.row(vec![
                format!("{lambda:.3e}"),
                variant.into(),
                out.distinct_values().to_string(),
                f(out.l2_loss),
                f(tr),
                f(te),
            ]);
        }
    }
    rep.table(table);
    rep.text(
        "Expected shape (paper §3.3/Fig 4): at equal λ1 the l1+l2 variant yields fewer \
         distinct values and a smaller l2 loss; large λ2 is numerically unstable.",
    );
    Ok(rep)
}

const FIG5_METHODS: [QuantMethod; 4] = [
    QuantMethod::IterativeL1,
    QuantMethod::KMeans,
    QuantMethod::ClusterLs,
    QuantMethod::L1LeastSquare,
];

/// E5 / Figure 5 — digit-image quantization: loss + runtime (+ rendered
/// images in the text report, PGM files beside the CSVs).
pub fn fig5(report_dir: Option<&std::path::Path>) -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 5 — digit-image quantization (hard-sigmoid clamped to [0,1]).");
    let image = workloads::digit_image();
    let mut table = Table::new(
        "Fig5 image quantization",
        &["method", "requested", "achieved", "l2_loss", "clamped", "seconds"],
    );
    for method in FIG5_METHODS {
        for &k in &[2usize, 4, 8, 16, 32, 64] {
            let opts = QuantOptions {
                target_values: k,
                lambda1: if method == QuantMethod::L1LeastSquare {
                    // λ chosen per-k by a short inner sweep for the
                    // λ-taking method.
                    lambda_for_count(&image, k)
                } else {
                    1e-4
                },
                clamp: Some((0.0, 1.0)),
                seed: 42,
                ..Default::default()
            };
            let (out, dt) = timed(&image, method, &opts)?;
            table.row(vec![
                method.id().into(),
                k.to_string(),
                out.distinct_values().to_string(),
                f(out.l2_loss),
                out.clamped.to_string(),
                secs(dt),
            ]);
            if k == 8 {
                rep.text(format!(
                    "{} @ k=8 (achieved {}):\n{}",
                    method.id(),
                    out.distinct_values(),
                    synth_digits::to_ascii(&out.values)
                ));
                if let Some(dir) = report_dir {
                    std::fs::create_dir_all(dir)?;
                    std::fs::write(
                        dir.join(format!("fig5_{}_k8.pgm", method.id())),
                        synth_digits::to_pgm(&out.values),
                    )?;
                }
            }
        }
    }
    rep.table(table);
    Ok(rep)
}

/// Pick a λ₁ that yields roughly `k` distinct values on `data` (short
/// bisection; used where the paper sweeps λ to hit counts).
pub fn lambda_for_count(data: &[f64], k: usize) -> f64 {
    // Bracket scaled to the data: λ = ½‖w‖² kills every coordinate.
    let wsq: f64 = data.iter().map(|x| x * x).sum();
    let mut lo = 1e-9 * wsq.max(1e-6);
    let mut hi = wsq.max(10.0);
    for _ in 0..18 {
        let mid = (lo * hi).sqrt();
        let opts = QuantOptions { lambda1: mid, ..Default::default() };
        match quant::quantize(data, QuantMethod::L1, &opts) {
            Ok(out) if out.distinct_values() > k => lo = mid,
            Ok(_) => hi = mid,
            Err(_) => hi = mid,
        }
    }
    (lo * hi).sqrt()
}

/// E6 / Figure 6 — the l0 method on the digit image: achieved counts,
/// losses, and the failure modes.
pub fn fig6() -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 6 — l0 best-subset on the digit image (non-universality on display).");
    let image = workloads::digit_image();
    let mut table = Table::new(
        "Fig6 l0 image quantization",
        &["requested_l", "achieved", "l2_loss", "unstable", "seconds"],
    );
    for &l in &[2usize, 4, 8, 16, 32, 64, 101, 128] {
        let opts = QuantOptions {
            target_values: l,
            clamp: Some((0.0, 1.0)),
            ..Default::default()
        };
        let (out, dt) = timed(&image, QuantMethod::L0, &opts)?;
        table.row(vec![
            l.to_string(),
            out.distinct_values().to_string(),
            f(out.l2_loss),
            out.diag.unstable.to_string(),
            secs(dt),
        ]);
    }
    rep.table(table);
    rep.text(
        "Expected (paper §4.2/Fig 6): good loss where it succeeds, achieved counts \
         often below the request (non-universal), failure beyond the package's l≤100 \
         limit and at large l.",
    );
    Ok(rep)
}

/// E7 / Figure 7 — the three synthetic source distributions as histograms.
pub fn fig7() -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 7 — artificially-generated data distributions (500 samples, [0,100]).");
    for (kind, data) in workloads::synth_datasets(1) {
        let h = histogram(&data, 0.0, 100.0, 20);
        let max = h.iter().copied().max().unwrap_or(1).max(1);
        let mut text = format!("\n{} (mean {:.1}, std {:.1})\n", kind.label(),
            stats::mean(&data), stats::std_dev(&data));
        for (b, &c) in h.iter().enumerate() {
            let bar = "#".repeat(c * 50 / max);
            text.push_str(&format!("{:>3}-{:<3} {:>3} {}\n", b * 5, (b + 1) * 5, c, bar));
        }
        rep.text(text);
        let mut t = Table::new(
            &format!("Fig7 histogram {}", kind.label()),
            &["bin_lo", "bin_hi", "count"],
        );
        for (b, &c) in h.iter().enumerate() {
            t.row(vec![(b * 5).to_string(), ((b + 1) * 5).to_string(), c.to_string()]);
        }
        rep.table(t);
    }
    Ok(rep)
}

const FIG8_METHODS: [QuantMethod; 6] = [
    QuantMethod::IterativeL1,
    QuantMethod::L1LeastSquare,
    QuantMethod::KMeans,
    QuantMethod::ClusterLs,
    QuantMethod::Gmm,
    QuantMethod::DataTransform,
];

/// E8 / Figure 8 — loss + runtime on the three synthetic datasets.
pub fn fig8() -> Result<Report> {
    let mut rep = Report::new();
    rep.text("Figure 8 — synthetic-data quantization: clamped l2 loss and runtime.");
    for (kind, data) in workloads::synth_datasets(1) {
        let mut table = Table::new(
            &format!("Fig8 {}", kind.label()),
            &["method", "requested", "achieved", "l2_loss", "seconds"],
        );
        for method in FIG8_METHODS {
            for &k in &[2usize, 4, 8, 16, 32, 64] {
                let opts = QuantOptions {
                    target_values: k,
                    lambda1: if method == QuantMethod::L1LeastSquare {
                        lambda_for_count(&data, k)
                    } else {
                        1e-3
                    },
                    clamp: Some((0.0, 100.0)),
                    seed: 7,
                    ..Default::default()
                };
                let (out, dt) = timed(&data, method, &opts)?;
                table.row(vec![
                    method.id().into(),
                    k.to_string(),
                    out.distinct_values().to_string(),
                    f(out.l2_loss),
                    secs(dt),
                ]);
            }
        }
        rep.table(table);
    }
    rep.text(
        "Expected (paper §4.3/Fig 8): l1 alone loses more here than on NN/MNIST data \
         but is fast; with LS refit the loss gap to kmeans nearly closes; cluster_ls \
         edges out kmeans; data_transform trails on these skewed/multimodal sets.",
    );
    Ok(rep)
}

/// E9 / §3.6 — runtime crossover: CD-LASSO vs k-means as k approaches m.
pub fn crossover() -> Result<Report> {
    let mut rep = Report::new();
    rep.text(
        "§3.6 complexity crossover — k-means O(t·k·T·m) vs structured CD O(t·m) per the \
         paper's asymptotic argument; high-resolution quantization (k ∈ Θ(m)) favors l1.",
    );
    let mut table = Table::new(
        "Crossover kmeans vs l1",
        &["m", "k", "kmeans_s", "l1_ls_s", "ratio_kmeans_over_l1"],
    );
    let mut rng = Pcg32::seeded(9);
    for &m in &[256usize, 512, 1024, 2048] {
        let data: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
        for frac in [4usize, 2] {
            let k = m / frac;
            let opts_k = QuantOptions { target_values: k, seed: 1, ..Default::default() };
            let (_, t_kmeans) = timed(&data, QuantMethod::KMeans, &opts_k)?;
            let lambda = lambda_for_count(&data, k);
            let opts_l = QuantOptions { lambda1: lambda, ..Default::default() };
            let (_, t_l1) = timed(&data, QuantMethod::L1LeastSquare, &opts_l)?;
            table.row(vec![
                m.to_string(),
                k.to_string(),
                secs(t_kmeans),
                secs(t_l1),
                f(t_kmeans / t_l1.max(1e-12)),
            ]);
        }
    }
    rep.table(table);
    Ok(rep)
}

/// E10 / §4 claim 6 — out-of-range incidence: naively-initialized k-means
/// (the practice the paper critiques) vs the hardened k-means++ baseline
/// vs the LS methods, across seeds on the [0,1] digit image.
pub fn out_of_range() -> Result<Report> {
    use crate::cluster::kmeans::{kmeans_1d, KMeansConfig, KMeansInit};
    use crate::quant::unique::UniqueDecomp;

    let mut rep = Report::new();
    rep.text(
        "Out-of-range incidence — §4.2: 'K-means methods sometimes provide out-of-range \
         values when the number of clusters is large', attributed to bad random \
         initialization (empty clusters keep their init value). LS methods cannot \
         produce out-of-range values. Our default kmeans hardens init (k-means++ + \
         empty-cluster repair), so the pathology is reproduced with the classic naive \
         init the paper's baseline practice corresponds to.",
    );
    let image = workloads::digit_image();
    let u = UniqueDecomp::new(&image)?;
    let counts = u.weights();
    let mut table = Table::new(
        "Out-of-range incidence",
        &["method", "k", "seeds_with_oor", "max_oor_values", "empty_cluster_events"],
    );

    // (a) naive-init k-means, no repair — the critiqued practice.
    // (b) hardened k-means++ (our default).
    for (label, init, repair) in [
        ("kmeans_naive_init", KMeansInit::RandomValues, false),
        ("kmeans_plus_plus", KMeansInit::KMeansPP, true),
    ] {
        for &k in &[32usize, 64, 128] {
            let mut seeds_oor = 0usize;
            let mut max_oor = 0usize;
            let mut empties = 0usize;
            for seed in 0..20u64 {
                let km = kmeans_1d(
                    &u.values,
                    Some(&counts),
                    &KMeansConfig {
                        k,
                        restarts: 1,
                        seed,
                        init,
                        repair_empty: repair,
                        ..Default::default()
                    },
                )?;
                let quantized: Vec<f64> = u
                    .values
                    .iter()
                    .map(|&v| {
                        km.centroids[crate::cluster::kmeans::assign_sorted(v, &km.centroids)]
                    })
                    .collect();
                // An out-of-range *centroid* only harms if some value maps
                // to it OR it survives as a reported level; count levels.
                let oor_levels = km
                    .centroids
                    .iter()
                    .filter(|&&c| !(0.0..=1.0).contains(&c))
                    .count();
                let _ = quantized;
                if oor_levels > 0 {
                    seeds_oor += 1;
                }
                max_oor = max_oor.max(oor_levels);
                empties += km.empty_cluster_events;
            }
            table.row(vec![
                label.into(),
                k.to_string(),
                seeds_oor.to_string(),
                max_oor.to_string(),
                empties.to_string(),
            ]);
        }
    }
    // (c) the LS methods for contrast.
    for method in [QuantMethod::ClusterLs, QuantMethod::L1LeastSquare] {
        for &k in &[32usize, 64, 128] {
            let mut seeds_oor = 0usize;
            let mut max_oor = 0usize;
            for seed in 0..10u64 {
                let opts = QuantOptions {
                    target_values: k,
                    lambda1: lambda_for_count(&image, k),
                    seed,
                    kmeans_restarts: 1,
                    clamp: None,
                    ..Default::default()
                };
                let out = quant::quantize(&image, method, &opts)?;
                let oor = crate::quant::hard_sigmoid::count_out_of_range(&out.levels, 0.0, 1.0);
                if oor > 0 {
                    seeds_oor += 1;
                }
                max_oor = max_oor.max(oor);
            }
            table.row(vec![
                method.id().into(),
                k.to_string(),
                seeds_oor.to_string(),
                max_oor.to_string(),
                "0".into(),
            ]);
        }
    }
    rep.table(table);
    Ok(rep)
}

/// Ablations (DESIGN §5 extension row): exact solvers vs the heuristics
/// the paper (and this repo) use, plus the baselines the paper discussed
/// but excluded (§2: fuzzy c-means; ref [11]: agglomerative).
pub fn ablations() -> Result<Report> {
    let mut rep = Report::new();
    rep.text(
        "Ablations — how much loss is the heuristic vs the objective: Lloyd vs exact DP \
         k-means; CD-LASSO (Alg 1) vs the exact fused-lasso DP on eq 6; and the \
         discussed-but-excluded baselines (fuzzy c-means §2, agglomerative [11]).",
    );
    let mut table = Table::new(
        "Ablations exact vs heuristic",
        &["dataset", "method", "k_or_λ", "achieved", "l2_loss", "seconds"],
    );
    for (kind, data) in workloads::synth_datasets(1) {
        for &k in &[8usize, 32] {
            for method in [
                QuantMethod::KMeans,
                QuantMethod::KMeansExact,
                QuantMethod::FuzzyCMeans,
                QuantMethod::Agglomerative,
                QuantMethod::ClusterLs,
            ] {
                let opts = QuantOptions { target_values: k, seed: 3, ..Default::default() };
                let (out, dt) = timed(&data, method, &opts)?;
                table.row(vec![
                    kind.label().into(),
                    method.id().into(),
                    k.to_string(),
                    out.distinct_values().to_string(),
                    f(out.l2_loss),
                    secs(dt),
                ]);
            }
        }
        // CD vs exact TV at matched λ.
        for lambda in [0.5f64, 5.0] {
            for method in [QuantMethod::L1, QuantMethod::L1LeastSquare, QuantMethod::TvExact] {
                let opts = QuantOptions { lambda1: lambda, refit: false, ..Default::default() };
                let (out, dt) = timed(&data, method, &opts)?;
                table.row(vec![
                    kind.label().into(),
                    method.id().into(),
                    format!("λ={lambda}"),
                    out.distinct_values().to_string(),
                    f(out.l2_loss),
                    secs(dt),
                ]);
            }
        }
    }
    rep.table(table);
    rep.text(
        "Expected: kmeans_exact ≤ kmeans (how much Lloyd leaves on the table); \
         tv_exact ≤ l1 at equal λ (CD truncation cost), with l1_ls recovering most of \
         the gap via the refit; fcm ≈ kmeans but slower (the Wen & Celebi claim the \
         paper cites); agglom deterministic and competitive.",
    );
    Ok(rep)
}

/// Bit-width experiment (the paper's intro motivation: "reduce the number
/// of distinct values to the nearest 2^k to reduce memory cost yet
/// preserve most of the information"): accuracy + compression at
/// power-of-two codebook sizes on the NN last layer.
pub fn bitwidth(nn: &super::workloads::NnWorkload) -> Result<Report> {
    use crate::quant::codebook::Codebook;
    let mut rep = Report::new();
    rep.text(format!(
        "Bit-width sweep — last layer to 2^b values (baseline train {:.4} / test {:.4}).",
        nn.train_acc, nn.test_acc
    ));
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut table = Table::new(
        "Bitwidth sweep",
        &[
            "bits",
            "values",
            "method",
            "test_acc",
            "bits_per_weight",
            "index_entropy",
            "compression_vs_f32",
        ],
    );
    // The standard compression-accounting columns (shared with the CLI
    // summaries), one row per (bits, method) cell of the sweep.
    let mut accounting = Table::compression("Bitwidth compression accounting");
    for bits in 1..=7u32 {
        let k = 1usize << bits;
        for method in [QuantMethod::KMeans, QuantMethod::ClusterLs, QuantMethod::IterativeL1] {
            let opts = QuantOptions {
                target_values: k,
                lambda1: 1e-3,
                seed: 42,
                ..Default::default()
            };
            let out = quant::quantize(&weights, method, &opts)?;
            let (_, te) =
                workloads::accuracy_with_layer(&nn.mlp, 3, &out.values, &nn.train, &nn.test)?;
            let cb = Codebook::from_output(&out)?;
            table.row(vec![
                bits.to_string(),
                cb.k().to_string(),
                method.id().into(),
                f(te),
                cb.bits_per_index().to_string(),
                f(cb.index_entropy()),
                format!("{:.1}x", cb.compression_ratio_f32()),
            ]);
            accounting.compression_row(&format!("b{bits}/{}", method.id()), &cb.stats(k));
        }
    }
    rep.table(table);
    rep.table(accounting);
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_sane() {
        assert!(lambda_grid().len() >= 8);
        assert!(count_grid(640).contains(&128));
        assert!(!count_grid(10).contains(&128));
    }

    #[test]
    fn lambda_for_count_brackets() {
        let mut rng = Pcg32::seeded(3);
        let data: Vec<f64> = (0..100).map(|_| rng.uniform(0.0, 1.0)).collect();
        let lam = lambda_for_count(&data, 8);
        let out = quant::quantize(
            &data,
            QuantMethod::L1,
            &QuantOptions { lambda1: lam, ..Default::default() },
        )
        .unwrap();
        // Bisection is approximate; within a small factor is fine.
        assert!(
            out.distinct_values() >= 2 && out.distinct_values() <= 32,
            "got {}",
            out.distinct_values()
        );
    }

    #[test]
    fn fig7_runs() {
        let rep = fig7().unwrap();
        let dir = std::env::temp_dir().join("sqlsq_fig7_test");
        rep.write(&dir, "fig7").unwrap();
        assert!(dir.join("fig7.txt").exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fig6_runs_and_shows_failure_mode() {
        let rep = fig6().unwrap();
        // The l>100 row must be flagged unstable.
        let table_text = rep
            .write(&std::env::temp_dir().join("sqlsq_fig6_test"), "fig6")
            .map(|_| {
                std::fs::read_to_string(
                    std::env::temp_dir().join("sqlsq_fig6_test").join("fig6.txt"),
                )
                .unwrap()
            })
            .unwrap();
        assert!(table_text.contains("101"));
        assert!(table_text.contains("true"));
        std::fs::remove_dir_all(std::env::temp_dir().join("sqlsq_fig6_test")).ok();
    }

    #[test]
    fn out_of_range_runs_smoke() {
        // Full E10 is slow; smoke-test the core loop on one config.
        let image = workloads::digit_image();
        let opts = QuantOptions {
            target_values: 64,
            seed: 3,
            kmeans_restarts: 1,
            clamp: None,
            ..Default::default()
        };
        let out = quant::quantize(&image, QuantMethod::KMeans, &opts).unwrap();
        let _ = crate::quant::hard_sigmoid::count_out_of_range(&out.values, 0.0, 1.0);
    }
}
