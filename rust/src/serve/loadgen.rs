//! Closed-loop load generator for a running [`super::Server`]: N client
//! connections, a deterministic multi-tenant job mix, and a latency /
//! shed-rate report. Powers `sqlsq loadgen`, the serve bench, and the
//! CI smoke job.
//!
//! The mix is fully seeded — job `i`'s tenant, method, lane and data
//! depend only on `i` and [`LoadSpec::seed`] — so two runs against
//! equivalent servers draw identical offered load. `distinct` bounds
//! how many unique vectors the run cycles through, which makes it the
//! cache-hit-rate knob: `distinct = jobs` means all misses, small
//! `distinct` makes most jobs repeat submissions.

use super::client::{Client, WireReply};
use super::frame::Codec;
use super::protocol::WireRequest;
use crate::coordinator::Payload;
use crate::data::rng::Pcg32;
use crate::jsonio::Json;
use crate::quant::{Precision, QuantMethod, QuantOptions};
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// What load to offer (see the module docs for determinism notes).
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Server address, `host:port`.
    pub addr: String,
    /// Total jobs across all connections.
    pub jobs: usize,
    /// Concurrent client connections (each a thread).
    pub conns: usize,
    /// Tenant pool size; job `i` runs as `tenant-{i % tenants}`.
    pub tenants: usize,
    /// Payload codec for requests and results.
    pub codec: Codec,
    /// Unique vectors in the mix (the cache-hit knob).
    pub distinct: usize,
    /// Elements per vector.
    pub n: usize,
    /// Base seed for the deterministic mix.
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            addr: "127.0.0.1:7878".into(),
            jobs: 64,
            conns: 4,
            tenants: 2,
            codec: Codec::Binary,
            distinct: 8,
            n: 256,
            seed: 1,
        }
    }
}

/// What happened: counts, wall time, latency percentiles, per-tenant
/// completion shares.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Jobs that returned a result.
    pub completed: u64,
    /// Jobs shed by admission control or queue backpressure.
    pub shed: u64,
    /// Jobs that returned an error payload or hit a transport failure.
    pub errors: u64,
    /// Whole-run wall time.
    pub wall: Duration,
    /// Completed jobs per second of wall time.
    pub throughput: f64,
    /// Median request latency, microseconds (completed jobs only).
    pub p50_us: f64,
    /// 95th-percentile request latency, microseconds.
    pub p95_us: f64,
    /// 99th-percentile request latency, microseconds.
    pub p99_us: f64,
    /// Mean request latency, microseconds.
    pub mean_us: f64,
    /// Completed-job count per tenant id, sorted by tenant.
    pub per_tenant_completed: Vec<(String, u64)>,
    /// `shed / (completed + shed + errors)`.
    pub shed_rate: f64,
}

impl LoadReport {
    /// JSON form for bench emission and the CLI.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("completed", Json::Num(self.completed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("wall_ms", Json::Num(self.wall.as_secs_f64() * 1e3)),
            ("throughput_jobs_per_s", Json::Num(self.throughput)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("mean_us", Json::Num(self.mean_us)),
            ("shed_rate", Json::Num(self.shed_rate)),
            (
                "per_tenant_completed",
                Json::Obj(
                    self.per_tenant_completed
                        .iter()
                        .map(|(t, c)| (t.clone(), Json::Num(*c as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "completed {} | shed {} ({:.1}%) | errors {} | {:.1} jobs/s | \
             p50 {:.0}us p95 {:.0}us p99 {:.0}us",
            self.completed,
            self.shed,
            self.shed_rate * 100.0,
            self.errors,
            self.throughput,
            self.p50_us,
            self.p95_us,
            self.p99_us
        )
    }
}

/// The deterministic request for job `i` under `spec`.
fn job_request(spec: &LoadSpec, i: usize) -> WireRequest {
    let distinct = spec.distinct.max(1);
    let mut rng = Pcg32::new(spec.seed.wrapping_add((i % distinct) as u64), 77);
    let n = spec.n.max(4);
    // Two clusters plus noise: structured enough that every method in
    // the mix produces a non-trivial codebook.
    let data: Vec<f64> = (0..n)
        .map(|j| {
            let base = if j % 2 == 0 { 1.0 } else { -1.0 };
            base + rng.uniform(-0.25, 0.25)
        })
        .collect();
    let (method, opts) = match i % 4 {
        0 => (
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.05, ..Default::default() },
        ),
        1 => (QuantMethod::KMeans, QuantOptions { target_values: 4, ..Default::default() }),
        2 => (
            QuantMethod::ClusterLs,
            QuantOptions { target_values: 8, ..Default::default() },
        ),
        _ => (QuantMethod::L1, QuantOptions { lambda1: 0.01, ..Default::default() }),
    };
    // Slot 3 carries non-uniform importance weights, exercising the
    // weighted native lane and the weight-salted cache keys. Drawing
    // them after `data` from the same rng keeps slots 0..2 bit-identical
    // to the unweighted mix.
    let weights: Option<Vec<f64>> =
        if i % 4 == 3 { Some((0..n).map(|_| rng.uniform(0.5, 2.0)).collect()) } else { None };
    let lane_f32 = i % 3 == 2;
    let opts = QuantOptions {
        precision: if lane_f32 { Precision::F32 } else { Precision::F64 },
        ..opts
    };
    let payload = if lane_f32 {
        Payload::F32(data.iter().map(|&x| x as f32).collect::<Vec<_>>().into())
    } else {
        Payload::F64(data.into())
    };
    WireRequest { method, opts, payload, weights }
}

/// Per-worker tallies, merged after the join.
#[derive(Default)]
struct Tally {
    completed: u64,
    shed: u64,
    errors: u64,
    latencies_us: Vec<f64>,
    per_tenant: BTreeMap<String, u64>,
}

fn run_worker(spec: &LoadSpec, worker: usize) -> Tally {
    let mut t = Tally::default();
    let mut client = match Client::connect(&spec.addr, spec.codec, None) {
        Ok(c) => c,
        Err(_) => {
            // Count every job this worker owned as a transport error.
            t.errors = (worker..spec.jobs).step_by(spec.conns.max(1)).count() as u64;
            return t;
        }
    };
    let tenants = spec.tenants.max(1);
    let mut i = worker;
    while i < spec.jobs {
        let tenant = format!("tenant-{}", i % tenants);
        let req = job_request(spec, i);
        let started = Instant::now();
        match client.quant_as(Some(&tenant), &req) {
            Ok(WireReply::Result(_)) => {
                t.completed += 1;
                t.latencies_us.push(started.elapsed().as_secs_f64() * 1e6);
                *t.per_tenant.entry(tenant).or_insert(0) += 1;
            }
            Ok(WireReply::Shed { .. }) => t.shed += 1,
            Ok(WireReply::Error(_)) => t.errors += 1,
            Err(_) => {
                // Transport failure (e.g. the server closed a draining
                // connection). Reconnect once; if that fails, charge the
                // remaining jobs as errors and stop.
                t.errors += 1;
                match Client::connect(&spec.addr, spec.codec, None) {
                    Ok(c) => client = c,
                    Err(_) => {
                        let mut rest = i + spec.conns.max(1);
                        while rest < spec.jobs {
                            t.errors += 1;
                            rest += spec.conns.max(1);
                        }
                        break;
                    }
                }
            }
        }
        i += spec.conns.max(1);
    }
    t
}

/// Offer the whole mix and report. Errs only on total transport failure
/// (zero jobs got any response at all); sheds and per-job errors are
/// data, not failures — callers decide what rate is acceptable.
pub fn run(spec: &LoadSpec) -> Result<LoadReport> {
    if spec.jobs == 0 {
        return Err(Error::Config("loadgen: jobs must be > 0".into()));
    }
    let conns = spec.conns.clamp(1, spec.jobs);
    let started = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(conns);
        for w in 0..conns {
            let spec_ref = &*spec;
            handles.push(s.spawn(move || run_worker(spec_ref, w)));
        }
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    });
    let wall = started.elapsed();

    let mut completed = 0u64;
    let mut shed = 0u64;
    let mut errors = 0u64;
    let mut lats: Vec<f64> = Vec::new();
    let mut per_tenant: BTreeMap<String, u64> = BTreeMap::new();
    for t in tallies {
        completed += t.completed;
        shed += t.shed;
        errors += t.errors;
        lats.extend(t.latencies_us);
        for (k, v) in t.per_tenant {
            *per_tenant.entry(k).or_insert(0) += v;
        }
    }
    let answered = completed + shed + errors;
    if completed + shed == 0 {
        return Err(Error::Runtime(format!(
            "loadgen: no job got a response from {} ({errors} transport errors)",
            spec.addr
        )));
    }
    lats.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let pct = |p: f64| -> f64 {
        if lats.is_empty() {
            return 0.0;
        }
        let idx = ((p * lats.len() as f64).ceil() as usize).saturating_sub(1);
        lats[idx.min(lats.len() - 1)]
    };
    let mean = if lats.is_empty() { 0.0 } else { lats.iter().sum::<f64>() / lats.len() as f64 };
    Ok(LoadReport {
        completed,
        shed,
        errors,
        wall,
        throughput: completed as f64 / wall.as_secs_f64().max(1e-9),
        p50_us: pct(0.50),
        p95_us: pct(0.95),
        p99_us: pct(0.99),
        mean_us: mean,
        per_tenant_completed: per_tenant.into_iter().collect(),
        shed_rate: shed as f64 / answered.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_mix_is_deterministic_and_respects_distinct() {
        let spec = LoadSpec { distinct: 2, ..Default::default() };
        let a = job_request(&spec, 0);
        let b = job_request(&spec, 0);
        let (Payload::F64(av), Payload::F64(bv)) = (&a.payload, &b.payload) else {
            panic!("job 0 is on the f64 lane");
        };
        assert_eq!(av.len(), bv.len());
        for (x, y) in av.iter().zip(bv.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "same job, same bits");
        }
        // distinct=2: job 4 reuses job 0's vector seed (and both are
        // method slot 0, f64 lane), while job 2 differs.
        let c = job_request(&spec, 4);
        let Payload::F64(cv) = &c.payload else { panic!("job 4 is on the f64 lane") };
        for (x, y) in av.iter().zip(cv.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "distinct cycles the data");
        }
        assert_eq!(a.method, QuantMethod::L1LeastSquare);
        assert_eq!(job_request(&spec, 1).method, QuantMethod::KMeans);
        assert_eq!(job_request(&spec, 2).opts.precision, Precision::F32);
    }

    #[test]
    fn slot_three_jobs_carry_deterministic_non_uniform_weights() {
        let spec = LoadSpec::default();
        for i in 0..4 {
            let req = job_request(&spec, i);
            assert_eq!(req.weights.is_some(), i % 4 == 3, "only slot 3 is weighted (job {i})");
        }
        let a = job_request(&spec, 3);
        let b = job_request(&spec, 3);
        let (wa, wb) = (a.weights.unwrap(), b.weights.unwrap());
        assert_eq!(wa.len(), spec.n.max(4));
        for (x, y) in wa.iter().zip(wb.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "weights are deterministic");
        }
        // Non-uniform, so the server's uniform-drop normalization keeps
        // them: these jobs genuinely exercise the weighted lane.
        assert!(wa.iter().any(|w| w.to_bits() != wa[0].to_bits()));
        assert!(wa.iter().all(|w| w.is_finite() && *w > 0.0));
    }

    #[test]
    fn report_json_has_the_series_the_bench_asserts_on() {
        let r = LoadReport {
            completed: 10,
            shed: 2,
            errors: 0,
            wall: Duration::from_millis(100),
            throughput: 100.0,
            p50_us: 1.0,
            p95_us: 2.0,
            p99_us: 3.0,
            mean_us: 1.5,
            per_tenant_completed: vec![("tenant-0".into(), 6), ("tenant-1".into(), 4)],
            shed_rate: 2.0 / 12.0,
        };
        let j = r.to_json();
        for key in
            ["completed", "shed", "throughput_jobs_per_s", "p50_us", "p99_us", "shed_rate"]
        {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let per = j.get("per_tenant_completed").unwrap();
        assert_eq!(per.get("tenant-0").and_then(Json::as_usize), Some(6));
        assert!(!r.summary().is_empty());
    }

    #[test]
    fn zero_jobs_is_a_config_error() {
        let spec = LoadSpec { jobs: 0, ..Default::default() };
        assert!(matches!(run(&spec), Err(Error::Config(_))));
    }
}
