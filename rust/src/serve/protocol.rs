//! Payload codecs for the network serve protocol: the JSON wire forms
//! (debugging) and the compact binary forms (production), plus the
//! always-JSON SHED/error payloads.
//!
//! # JSON forms ([`Codec::Json`])
//!
//! Request (`FrameKind::Quant` payload):
//!
//! ```json
//! {
//!   "method": "kmeans",
//!   "lane":   "f64",
//!   "data":   [1.0, 2.5, 1.0],
//!   "weights": [1.0, 3.0, 1.0],
//!   "opts":   { "lambda1": 0.01, "target_values": 4, "seed": "0", ... }
//! }
//! ```
//!
//! `lane` picks the payload precision (`"f32"` data is narrowed from the
//! JSON numbers — exact for values that originated as f32). Every
//! [`QuantOptions`] field rides in `opts`; `seed` is a **decimal string**
//! because a u64 exceeds the integer range a JSON number (f64) carries
//! exactly. `clamp` is `[lo, hi]` or `null`; `entropy_budget` is a number
//! (bits per value) or `null`. Omitted `opts` fields take their defaults;
//! unknown fields are ignored. `weights` is an optional per-element
//! importance array (always f64, one entry per `data` element) — omitted
//! or `null` means unweighted.
//!
//! Result (`FrameKind::Result` payload): the compact codebook-native
//! form — shared levels + one index per element, never a materialized
//! vector:
//!
//! ```json
//! {
//!   "id": 7, "served_by": "native", "lane": "f64",
//!   "levels_requested": 4, "l2_loss": 0.0125,
//!   "levels": [0.1, 0.5], "indices": [0, 1, 0]
//! }
//! ```
//!
//! Levels are the f64 surface on both lanes (f32 levels widen exactly,
//! so the round trip is lossless). JSON numbers round-trip f64 bitwise
//! (Rust's shortest-roundtrip `Display`), with one documented exception:
//! `-0.0` serializes as `0` — ship binary if negative-zero payload bits
//! matter.
//!
//! # Binary forms ([`Codec::Binary`])
//!
//! All integers little-endian; floats are IEEE-754 bit patterns (exact
//! by construction). Request:
//!
//! ```text
//! lane u8 (0=f64 1=f32) | method_id_len u8 | method_id bytes
//! | opts: lambda1 f64, lambda2 f64, target_values u64, max_epochs u64,
//!         tol f64, kmeans_restarts u64, max_iters u64, seed u64,
//!         refit u8, max_lambda_steps u64,
//!         clamp_tag u8 (0|1) [, lo f64, hi f64],
//!         precision u8 (0=f64 1=f32),
//!         entropy_budget_tag u8 (0|1) [, bits f64]
//! | n u64 | data: n × (f64|f32 per lane)
//! | weights_tag u8 (0|1) [, n × f64]
//! ```
//!
//! The importance weights ride after the data section (always f64 — the
//! weighted objective accumulates in the lane but the weights themselves
//! are exact on the wire); their count must equal `n`.
//!
//! Result:
//!
//! ```text
//! id u64 | served_by u8 (0=native 1=runtime 2=cache) | lane u8
//! | levels_requested u64 | l2_loss f64
//! | k u64 | levels: k × f64 | n u64 | indices: n × u32
//! ```
//!
//! # SHED / error payloads
//!
//! Always JSON, regardless of the request codec — they are tiny, rare,
//! and must stay readable in a hex dump:
//! `{"retry_after_ms": 40, "reason": "queue full"}` /
//! `{"error": "..."}`.
//!
//! Every decoder validates sizes/ids and rejects trailing bytes; a bad
//! payload is a request-level error (the connection survives), unlike
//! the frame-level violations of [`super::frame`].

use super::frame::Codec;
use crate::coordinator::Payload;
use crate::jsonio::{self, Json};
use crate::quant::{Precision, QuantMethod, QuantOptions};
use crate::{Error, Result};
use std::sync::Arc;

/// A decoded quantization request as it crosses the wire: the payload in
/// its submitted lane, the method, and the full option set.
#[derive(Debug, Clone)]
pub struct WireRequest {
    /// Algorithm to run.
    pub method: QuantMethod,
    /// Full options (the target level count rides in
    /// `opts.target_values`).
    pub opts: QuantOptions,
    /// The vector to quantize, in its lane.
    pub payload: Payload,
    /// Optional per-element importance weights (always f64, one entry
    /// per payload element). `None` means unweighted.
    pub weights: Option<Vec<f64>>,
}

/// A decoded quantization result: the compact codebook plus identity and
/// accounting fields. Client-side mirror of the coordinator's
/// `JobOutput` surface (levels on f64 — exact for both lanes).
#[derive(Debug, Clone, PartialEq)]
pub struct WireResult {
    /// Server-side job id.
    pub id: u64,
    /// Which engine served it: "native" | "runtime" | "cache".
    pub served_by: String,
    /// The lane the job was solved on.
    pub lane: Precision,
    /// The level count the request asked for.
    pub levels_requested: usize,
    /// Squared-l2 information loss.
    pub l2_loss: f64,
    /// Distinct quantization levels, ascending, f64 surface.
    pub levels: Vec<f64>,
    /// One index per input element into `levels`.
    pub indices: Vec<u32>,
}

impl WireResult {
    /// Materialize the full-length quantized vector (edge decode).
    pub fn decode(&self) -> Vec<f64> {
        self.indices.iter().map(|&i| self.levels[i as usize]).collect()
    }
}

fn bad(what: &str, msg: &str) -> Error {
    Error::InvalidInput(format!("{what} wire: {msg}"))
}

// ---------------------------------------------------------------------
// JSON codec
// ---------------------------------------------------------------------

fn opts_to_json(o: &QuantOptions) -> Json {
    Json::obj(vec![
        ("lambda1", Json::Num(o.lambda1)),
        ("lambda2", Json::Num(o.lambda2)),
        ("target_values", Json::Num(o.target_values as f64)),
        ("max_epochs", Json::Num(o.max_epochs as f64)),
        ("tol", Json::Num(o.tol)),
        ("kmeans_restarts", Json::Num(o.kmeans_restarts as f64)),
        ("max_iters", Json::Num(o.max_iters as f64)),
        ("seed", Json::Str(o.seed.to_string())),
        ("refit", Json::Bool(o.refit)),
        ("max_lambda_steps", Json::Num(o.max_lambda_steps as f64)),
        (
            "clamp",
            match o.clamp {
                None => Json::Null,
                Some((lo, hi)) => Json::Arr(vec![Json::Num(lo), Json::Num(hi)]),
            },
        ),
        ("precision", Json::Str(o.precision.id().into())),
        (
            "entropy_budget",
            match o.entropy_budget {
                None => Json::Null,
                Some(b) => Json::Num(b),
            },
        ),
    ])
}

fn opts_from_json(j: &Json) -> Result<QuantOptions> {
    let mut o = QuantOptions::default();
    let e = |m: &str| bad("request", m);
    if let Some(v) = j.get("lambda1") {
        o.lambda1 = v.as_f64().ok_or_else(|| e("'lambda1' must be a number"))?;
    }
    if let Some(v) = j.get("lambda2") {
        o.lambda2 = v.as_f64().ok_or_else(|| e("'lambda2' must be a number"))?;
    }
    if let Some(v) = j.get("target_values") {
        o.target_values = v.as_usize().ok_or_else(|| e("'target_values' must be an integer"))?;
    }
    if let Some(v) = j.get("max_epochs") {
        o.max_epochs = v.as_usize().ok_or_else(|| e("'max_epochs' must be an integer"))?;
    }
    if let Some(v) = j.get("tol") {
        o.tol = v.as_f64().ok_or_else(|| e("'tol' must be a number"))?;
    }
    if let Some(v) = j.get("kmeans_restarts") {
        o.kmeans_restarts =
            v.as_usize().ok_or_else(|| e("'kmeans_restarts' must be an integer"))?;
    }
    if let Some(v) = j.get("max_iters") {
        o.max_iters = v.as_usize().ok_or_else(|| e("'max_iters' must be an integer"))?;
    }
    if let Some(v) = j.get("seed") {
        let s = v.as_str().ok_or_else(|| e("'seed' must be a decimal string"))?;
        o.seed = s.parse().map_err(|_| e("'seed' must be a decimal u64 string"))?;
    }
    if let Some(v) = j.get("refit") {
        o.refit = v.as_bool().ok_or_else(|| e("'refit' must be a bool"))?;
    }
    if let Some(v) = j.get("max_lambda_steps") {
        o.max_lambda_steps =
            v.as_usize().ok_or_else(|| e("'max_lambda_steps' must be an integer"))?;
    }
    match j.get("clamp") {
        None | Some(Json::Null) => {}
        Some(v) => {
            let arr = v.as_arr().ok_or_else(|| e("'clamp' must be [lo, hi] or null"))?;
            if arr.len() != 2 {
                return Err(e("'clamp' must have exactly two elements"));
            }
            let lo = arr[0].as_f64().ok_or_else(|| e("'clamp' elements must be numbers"))?;
            let hi = arr[1].as_f64().ok_or_else(|| e("'clamp' elements must be numbers"))?;
            o.clamp = Some((lo, hi));
        }
    }
    if let Some(v) = j.get("precision") {
        let s = v.as_str().ok_or_else(|| e("'precision' must be \"f64\" or \"f32\""))?;
        o.precision =
            Precision::from_id(s).ok_or_else(|| e("'precision' must be \"f64\" or \"f32\""))?;
    }
    match j.get("entropy_budget") {
        None | Some(Json::Null) => {}
        Some(v) => {
            o.entropy_budget =
                Some(v.as_f64().ok_or_else(|| e("'entropy_budget' must be a number or null"))?);
        }
    }
    Ok(o)
}

fn request_to_json(req: &WireRequest) -> Json {
    let data = match &req.payload {
        Payload::F64(v) => Json::Arr(v.iter().map(|&x| Json::Num(x)).collect()),
        Payload::F32(v) => Json::Arr(v.iter().map(|&x| Json::Num(f64::from(x))).collect()),
    };
    let mut fields = vec![
        ("method", Json::Str(req.method.id().into())),
        ("lane", Json::Str(req.payload.precision().id().into())),
        ("data", data),
        ("opts", opts_to_json(&req.opts)),
    ];
    if let Some(w) = &req.weights {
        fields.push(("weights", Json::Arr(w.iter().map(|&x| Json::Num(x)).collect())));
    }
    Json::obj(fields)
}

fn request_from_json(j: &Json) -> Result<WireRequest> {
    let e = |m: &str| bad("request", m);
    let method_id = j
        .get("method")
        .and_then(Json::as_str)
        .ok_or_else(|| e("missing string 'method'"))?;
    let method = QuantMethod::from_id(method_id)
        .ok_or_else(|| e(&format!("unknown method '{method_id}'")))?;
    let lane_id = j.get("lane").and_then(Json::as_str).unwrap_or("f64");
    let lane = Precision::from_id(lane_id)
        .ok_or_else(|| e(&format!("unknown lane '{lane_id}' (f64|f32)")))?;
    let data = j.get("data").and_then(Json::as_arr).ok_or_else(|| e("missing 'data' array"))?;
    let nums: Vec<f64> = data
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| e("non-numeric 'data' element")))
        .collect::<Result<_>>()?;
    let opts = match j.get("opts") {
        Some(o) => opts_from_json(o)?,
        None => QuantOptions::default(),
    };
    let weights = match j.get("weights") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let arr = v.as_arr().ok_or_else(|| e("'weights' must be an array or null"))?;
            Some(
                arr.iter()
                    .map(|w| w.as_f64().ok_or_else(|| e("non-numeric 'weights' element")))
                    .collect::<Result<Vec<f64>>>()?,
            )
        }
    };
    let payload = match lane {
        Precision::F64 => Payload::F64(nums.into()),
        Precision::F32 => {
            Payload::F32(nums.iter().map(|&x| x as f32).collect::<Vec<_>>().into())
        }
    };
    Ok(WireRequest { method, opts, payload, weights })
}

fn result_to_json(res: &WireResult) -> Json {
    Json::obj(vec![
        ("id", Json::Num(res.id as f64)),
        ("served_by", Json::Str(res.served_by.clone())),
        ("lane", Json::Str(res.lane.id().into())),
        ("levels_requested", Json::Num(res.levels_requested as f64)),
        ("l2_loss", Json::Num(res.l2_loss)),
        ("levels", Json::Arr(res.levels.iter().map(|&v| Json::Num(v)).collect())),
        (
            "indices",
            Json::Arr(res.indices.iter().map(|&i| Json::Num(f64::from(i))).collect()),
        ),
    ])
}

fn result_from_json(j: &Json) -> Result<WireResult> {
    let e = |m: &str| bad("result", m);
    let levels: Vec<f64> = j
        .get("levels")
        .and_then(Json::as_arr)
        .ok_or_else(|| e("missing 'levels' array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| e("non-numeric level")))
        .collect::<Result<_>>()?;
    let indices: Vec<u32> = j
        .get("indices")
        .and_then(Json::as_arr)
        .ok_or_else(|| e("missing 'indices' array"))?
        .iter()
        .map(|v| {
            let i = v.as_usize().ok_or_else(|| e("index not a non-negative integer"))?;
            if i >= levels.len() {
                return Err(e("index out of range of 'levels'"));
            }
            Ok(i as u32)
        })
        .collect::<Result<_>>()?;
    let lane_id = j.get("lane").and_then(Json::as_str).unwrap_or("f64");
    Ok(WireResult {
        id: j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        served_by: j
            .get("served_by")
            .and_then(Json::as_str)
            .unwrap_or("native")
            .to_string(),
        lane: Precision::from_id(lane_id).ok_or_else(|| e("unknown 'lane'"))?,
        levels_requested: j
            .get("levels_requested")
            .and_then(Json::as_usize)
            .unwrap_or(levels.len()),
        l2_loss: j.get("l2_loss").and_then(Json::as_f64).unwrap_or(0.0),
        levels,
        indices,
    })
}

// ---------------------------------------------------------------------
// Binary codec
// ---------------------------------------------------------------------

/// Byte-stream writer helpers for the binary forms.
struct Enc {
    out: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { out: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
}

/// Byte-stream reader over one payload; rejects short reads and (via
/// [`Dec::finish`]) trailing bytes.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8], what: &'static str) -> Dec<'a> {
        Dec { buf, pos: 0, what }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| bad(self.what, "payload truncated"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    /// Length-prefix sanity: a claimed element count can never imply more
    /// bytes than remain in the payload.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        let need = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| bad(self.what, "length prefix overflows"))?;
        if self.pos + need > self.buf.len() {
            return Err(bad(self.what, "length prefix exceeds payload"));
        }
        Ok(n)
    }
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad(self.what, "trailing bytes after payload"));
        }
        Ok(())
    }
}

fn opts_to_bin(e: &mut Enc, o: &QuantOptions) {
    e.f64(o.lambda1);
    e.f64(o.lambda2);
    e.u64(o.target_values as u64);
    e.u64(o.max_epochs as u64);
    e.f64(o.tol);
    e.u64(o.kmeans_restarts as u64);
    e.u64(o.max_iters as u64);
    e.u64(o.seed);
    e.u8(u8::from(o.refit));
    e.u64(o.max_lambda_steps as u64);
    match o.clamp {
        None => e.u8(0),
        Some((lo, hi)) => {
            e.u8(1);
            e.f64(lo);
            e.f64(hi);
        }
    }
    e.u8(match o.precision {
        Precision::F64 => 0,
        Precision::F32 => 1,
    });
    match o.entropy_budget {
        None => e.u8(0),
        Some(b) => {
            e.u8(1);
            e.f64(b);
        }
    }
}

fn opts_from_bin(d: &mut Dec<'_>) -> Result<QuantOptions> {
    let lambda1 = d.f64()?;
    let lambda2 = d.f64()?;
    let target_values = d.u64()? as usize;
    let max_epochs = d.u64()? as usize;
    let tol = d.f64()?;
    let kmeans_restarts = d.u64()? as usize;
    let max_iters = d.u64()? as usize;
    let seed = d.u64()?;
    let refit = match d.u8()? {
        0 => false,
        1 => true,
        b => return Err(bad(d.what, &format!("bad refit byte {b}"))),
    };
    let max_lambda_steps = d.u64()? as usize;
    let clamp = match d.u8()? {
        0 => None,
        1 => Some((d.f64()?, d.f64()?)),
        b => return Err(bad(d.what, &format!("bad clamp tag {b}"))),
    };
    let precision = match d.u8()? {
        0 => Precision::F64,
        1 => Precision::F32,
        b => return Err(bad(d.what, &format!("bad precision byte {b}"))),
    };
    let entropy_budget = match d.u8()? {
        0 => None,
        1 => Some(d.f64()?),
        b => return Err(bad(d.what, &format!("bad entropy_budget tag {b}"))),
    };
    Ok(QuantOptions {
        lambda1,
        lambda2,
        target_values,
        max_epochs,
        tol,
        kmeans_restarts,
        max_iters,
        seed,
        refit,
        max_lambda_steps,
        clamp,
        precision,
        entropy_budget,
    })
}

fn request_to_bin(req: &WireRequest) -> Vec<u8> {
    let mut e = Enc::new();
    e.u8(match req.payload.precision() {
        Precision::F64 => 0,
        Precision::F32 => 1,
    });
    let id = req.method.id();
    e.u8(id.len() as u8);
    e.out.extend_from_slice(id.as_bytes());
    opts_to_bin(&mut e, &req.opts);
    match &req.payload {
        Payload::F64(v) => {
            e.u64(v.len() as u64);
            for &x in v.iter() {
                e.f64(x);
            }
        }
        Payload::F32(v) => {
            e.u64(v.len() as u64);
            for &x in v.iter() {
                e.f32(x);
            }
        }
    }
    match &req.weights {
        None => e.u8(0),
        Some(w) => {
            e.u8(1);
            for &x in w {
                e.f64(x);
            }
        }
    }
    e.out
}

fn request_from_bin(buf: &[u8]) -> Result<WireRequest> {
    let mut d = Dec::new(buf, "request");
    let lane = match d.u8()? {
        0 => Precision::F64,
        1 => Precision::F32,
        b => return Err(bad("request", &format!("bad lane byte {b}"))),
    };
    let id_len = d.u8()? as usize;
    let id_bytes = d.take(id_len)?;
    let id = std::str::from_utf8(id_bytes)
        .map_err(|_| bad("request", "method id is not UTF-8"))?;
    let method =
        QuantMethod::from_id(id).ok_or_else(|| bad("request", "unknown method id"))?;
    let opts = opts_from_bin(&mut d)?;
    let payload = match lane {
        Precision::F64 => {
            let n = d.len_prefix(8)?;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(d.f64()?);
            }
            Payload::F64(Arc::from(v))
        }
        Precision::F32 => {
            let n = d.len_prefix(4)?;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(d.f32()?);
            }
            Payload::F32(Arc::from(v))
        }
    };
    let weights = match d.u8()? {
        0 => None,
        1 => {
            // The count is pinned to the payload length; no separate
            // length prefix to keep mismatched weights unrepresentable
            // on the binary wire.
            let n = payload.len();
            if d.pos + n * 8 > d.buf.len() {
                return Err(bad("request", "weights section exceeds payload"));
            }
            let mut w = Vec::with_capacity(n);
            for _ in 0..n {
                w.push(d.f64()?);
            }
            Some(w)
        }
        b => return Err(bad("request", &format!("bad weights tag {b}"))),
    };
    d.finish()?;
    Ok(WireRequest { method, opts, payload, weights })
}

fn result_to_bin(res: &WireResult) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(res.id);
    e.u8(match res.served_by.as_str() {
        "runtime" => 1,
        "cache" => 2,
        _ => 0,
    });
    e.u8(match res.lane {
        Precision::F64 => 0,
        Precision::F32 => 1,
    });
    e.u64(res.levels_requested as u64);
    e.f64(res.l2_loss);
    e.u64(res.levels.len() as u64);
    for &l in &res.levels {
        e.f64(l);
    }
    e.u64(res.indices.len() as u64);
    for &i in &res.indices {
        e.u32(i);
    }
    e.out
}

fn result_from_bin(buf: &[u8]) -> Result<WireResult> {
    let mut d = Dec::new(buf, "result");
    let id = d.u64()?;
    let served_by = match d.u8()? {
        0 => "native",
        1 => "runtime",
        2 => "cache",
        b => return Err(bad("result", &format!("bad served_by byte {b}"))),
    }
    .to_string();
    let lane = match d.u8()? {
        0 => Precision::F64,
        1 => Precision::F32,
        b => return Err(bad("result", &format!("bad lane byte {b}"))),
    };
    let levels_requested = d.u64()? as usize;
    let l2_loss = d.f64()?;
    let k = d.len_prefix(8)?;
    let mut levels = Vec::with_capacity(k);
    for _ in 0..k {
        levels.push(d.f64()?);
    }
    let n = d.len_prefix(4)?;
    let mut indices = Vec::with_capacity(n);
    for _ in 0..n {
        let i = d.u32()?;
        if i as usize >= levels.len() {
            return Err(bad("result", "index out of range of levels"));
        }
        indices.push(i);
    }
    d.finish()?;
    Ok(WireResult { id, served_by, lane, levels_requested, l2_loss, levels, indices })
}

// ---------------------------------------------------------------------
// Public codec surface
// ---------------------------------------------------------------------

/// Encode a request payload under `codec`.
pub fn encode_request(req: &WireRequest, codec: Codec) -> Vec<u8> {
    match codec {
        Codec::Json => request_to_json(req).to_string().into_bytes(),
        Codec::Binary => request_to_bin(req),
    }
}

/// Decode a request payload under `codec`. Errors are request-level
/// ([`Error::InvalidInput`]): the connection survives them.
pub fn decode_request(payload: &[u8], codec: Codec) -> Result<WireRequest> {
    match codec {
        Codec::Json => {
            let text = std::str::from_utf8(payload)
                .map_err(|_| bad("request", "payload is not UTF-8"))?;
            request_from_json(&jsonio::parse(text)?)
        }
        Codec::Binary => request_from_bin(payload),
    }
}

/// Encode a result payload under `codec`.
pub fn encode_result(res: &WireResult, codec: Codec) -> Vec<u8> {
    match codec {
        Codec::Json => result_to_json(res).to_string().into_bytes(),
        Codec::Binary => result_to_bin(res),
    }
}

/// Decode a result payload under `codec`.
pub fn decode_result(payload: &[u8], codec: Codec) -> Result<WireResult> {
    match codec {
        Codec::Json => {
            let text = std::str::from_utf8(payload)
                .map_err(|_| bad("result", "payload is not UTF-8"))?;
            result_from_json(&jsonio::parse(text)?)
        }
        Codec::Binary => result_from_bin(payload),
    }
}

/// Encode a SHED payload (always JSON; see the module docs).
pub fn encode_shed(retry_after_ms: u64, reason: &str) -> Vec<u8> {
    Json::obj(vec![
        ("retry_after_ms", Json::Num(retry_after_ms as f64)),
        ("reason", Json::Str(reason.into())),
    ])
    .to_string()
    .into_bytes()
}

/// Decode a SHED payload into `(retry_after_ms, reason)`.
pub fn decode_shed(payload: &[u8]) -> Result<(u64, String)> {
    let text =
        std::str::from_utf8(payload).map_err(|_| bad("shed", "payload is not UTF-8"))?;
    let j = jsonio::parse(text)?;
    let retry = j
        .get("retry_after_ms")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("shed", "missing integer 'retry_after_ms'"))? as u64;
    let reason = j.get("reason").and_then(Json::as_str).unwrap_or("").to_string();
    Ok((retry, reason))
}

/// Encode an error payload (always JSON; see the module docs).
pub fn encode_error(msg: &str) -> Vec<u8> {
    Json::obj(vec![("error", Json::Str(msg.into()))]).to_string().into_bytes()
}

/// Decode an error payload into its message.
pub fn decode_error(payload: &[u8]) -> Result<String> {
    let text =
        std::str::from_utf8(payload).map_err(|_| bad("error", "payload is not UTF-8"))?;
    let j = jsonio::parse(text)?;
    Ok(j.get("error").and_then(Json::as_str).unwrap_or("unknown error").to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request(lane: Precision) -> WireRequest {
        let opts = QuantOptions {
            lambda1: 0.037,
            target_values: 5,
            seed: u64::MAX - 17, // exceeds f64's exact integer range on purpose
            clamp: Some((-1.5, 2.5)),
            precision: lane,
            entropy_budget: Some(1.5 + 0.1), // non-terminating binary tail
            ..Default::default()
        };
        let payload = match lane {
            Precision::F64 => {
                Payload::F64(vec![1.25, -0.5, 3.75, 1.25, 0.1 + 0.2].into())
            }
            Precision::F32 => Payload::F32(vec![1.25f32, -0.5, 3.75, 0.3].into()),
        };
        let weights = Some((0..payload.len()).map(|i| 0.5 + 0.1 * i as f64).collect());
        WireRequest { method: QuantMethod::L1LeastSquare, opts, payload, weights }
    }

    fn payload_bits(p: &Payload) -> Vec<u64> {
        match p {
            Payload::F64(v) => v.iter().map(|x| x.to_bits()).collect(),
            Payload::F32(v) => v.iter().map(|x| u64::from(x.to_bits())).collect(),
        }
    }

    #[test]
    fn request_roundtrip_is_bitwise_on_both_codecs_and_lanes() {
        for codec in [Codec::Json, Codec::Binary] {
            for lane in [Precision::F64, Precision::F32] {
                let req = sample_request(lane);
                let back = decode_request(&encode_request(&req, codec), codec).unwrap();
                assert_eq!(back.method, req.method, "{codec:?}/{lane:?}");
                assert_eq!(
                    payload_bits(&back.payload),
                    payload_bits(&req.payload),
                    "{codec:?}/{lane:?}: payload bits"
                );
                assert!(
                    crate::quant::api::opts_bits_eq(&back.opts, &req.opts),
                    "{codec:?}/{lane:?}: option bits"
                );
                let (wa, wb) = (back.weights.as_ref().unwrap(), req.weights.as_ref().unwrap());
                assert_eq!(
                    wa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    wb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{codec:?}/{lane:?}: weight bits"
                );
            }
        }
    }

    #[test]
    fn unweighted_requests_carry_no_weights_section() {
        for codec in [Codec::Json, Codec::Binary] {
            let mut req = sample_request(Precision::F64);
            req.weights = None;
            req.opts.entropy_budget = None;
            let back = decode_request(&encode_request(&req, codec), codec).unwrap();
            assert!(back.weights.is_none(), "{codec:?}");
            assert!(back.opts.entropy_budget.is_none(), "{codec:?}");
        }
        // JSON also tolerates explicit nulls.
        let req = decode_request(
            br#"{"method":"kmeans","data":[1.0,2.0],"weights":null,"opts":{"entropy_budget":null}}"#,
            Codec::Json,
        )
        .unwrap();
        assert!(req.weights.is_none());
        assert!(req.opts.entropy_budget.is_none());
    }

    #[test]
    fn result_roundtrip_is_bitwise_on_both_codecs() {
        let res = WireResult {
            id: 42,
            served_by: "cache".into(),
            lane: Precision::F32,
            levels_requested: 4,
            l2_loss: 0.1 + 0.2, // a value with a non-terminating binary tail
            levels: vec![-2.5, 0.1 + 0.2, 7.0],
            indices: vec![0, 2, 1, 1, 0],
        };
        for codec in [Codec::Json, Codec::Binary] {
            let back = decode_result(&encode_result(&res, codec), codec).unwrap();
            assert_eq!(back, res, "{codec:?}");
            assert_eq!(back.l2_loss.to_bits(), res.l2_loss.to_bits());
            for (a, b) in back.levels.iter().zip(&res.levels) {
                assert_eq!(a.to_bits(), b.to_bits(), "{codec:?}");
            }
            assert_eq!(back.decode().len(), 5);
        }
    }

    #[test]
    fn shed_and_error_payloads_roundtrip() {
        let (ms, reason) = decode_shed(&encode_shed(40, "queue full")).unwrap();
        assert_eq!(ms, 40);
        assert_eq!(reason, "queue full");
        assert_eq!(decode_error(&encode_error("boom")).unwrap(), "boom");
        assert!(decode_shed(b"not json").is_err());
        assert!(decode_shed(b"{}").is_err(), "retry_after_ms is mandatory");
    }

    #[test]
    fn malformed_payloads_are_request_errors_not_panics() {
        for codec in [Codec::Json, Codec::Binary] {
            assert!(decode_request(&[], codec).is_err());
            assert!(decode_request(&[0xff; 7], codec).is_err());
            assert!(decode_result(&[], codec).is_err());
            assert!(decode_result(&[0x01, 0x02], codec).is_err());
        }
        // JSON-specific: valid JSON, wrong shape.
        assert!(decode_request(br#"{"data":[1]}"#, Codec::Json).is_err(), "missing method");
        assert!(
            decode_request(br#"{"method":"nope","data":[1]}"#, Codec::Json).is_err(),
            "unknown method"
        );
        assert!(
            decode_request(br#"{"method":"kmeans","lane":"f16","data":[1]}"#, Codec::Json)
                .is_err(),
            "unknown lane"
        );
        assert!(
            decode_request(
                br#"{"method":"kmeans","data":[1],"opts":{"seed":5}}"#,
                Codec::Json
            )
            .is_err(),
            "seed must be a decimal string"
        );
        assert!(
            decode_request(br#"{"method":"kmeans","data":[1],"weights":["x"]}"#, Codec::Json)
                .is_err(),
            "non-numeric weight"
        );
        assert!(
            decode_request(br#"{"method":"kmeans","data":[1],"weights":3}"#, Codec::Json)
                .is_err(),
            "weights must be an array"
        );
        // Binary-specific: a valid prefix with trailing garbage.
        let mut good = encode_request(&sample_request(Precision::F64), Codec::Binary);
        good.push(0);
        assert!(decode_request(&good, Codec::Binary).is_err(), "trailing byte");
        // A bad weights tag (the final byte of an unweighted request).
        let mut unweighted = sample_request(Precision::F64);
        unweighted.weights = None;
        let mut bin_req = encode_request(&unweighted, Codec::Binary);
        *bin_req.last_mut().unwrap() = 2;
        assert!(decode_request(&bin_req, Codec::Binary).is_err(), "bad weights tag");
        // Truncation at every prefix either errors or never panics.
        let full = encode_request(&sample_request(Precision::F64), Codec::Binary);
        for cut in 0..full.len() {
            assert!(decode_request(&full[..cut], Codec::Binary).is_err(), "cut={cut}");
        }
        // A length prefix larger than the payload is rejected up front
        // (no huge allocation attempt).
        let res = WireResult {
            id: 1,
            served_by: "native".into(),
            lane: Precision::F64,
            levels_requested: 2,
            l2_loss: 0.0,
            levels: vec![1.0],
            indices: vec![0],
        };
        let mut bin = encode_result(&res, Codec::Binary);
        // levels count lives at offset 8+1+1+8+8 = 26.
        bin[26..34].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_result(&bin, Codec::Binary).is_err());
    }

    #[test]
    fn json_request_defaults_apply_for_omitted_fields() {
        let req =
            decode_request(br#"{"method":"kmeans","data":[1.0,2.0]}"#, Codec::Json).unwrap();
        assert_eq!(req.method, QuantMethod::KMeans);
        assert_eq!(req.payload.precision(), Precision::F64);
        let d = QuantOptions::default();
        assert_eq!(req.opts.target_values, d.target_values);
        assert_eq!(req.opts.seed, d.seed);
        assert_eq!(req.opts.refit, d.refit);
    }
}
