//! Network serve front end: a socket server over the
//! [`Coordinator`](crate::coordinator::Coordinator) with admission
//! control, per-tenant fairness, and load shedding.
//!
//! The stack, bottom up:
//!
//! * [`frame`] — length-prefixed framing over a TCP stream: a fixed
//!   12-byte header (magic, version, kind, codec, tenant length,
//!   payload length), then the tenant id and payload. Malformed
//!   headers are protocol violations (connection closes); oversized
//!   claims are rejected before allocation.
//! * [`protocol`] — the payload codecs: jsonio JSON (debuggable) and
//!   compact little-endian binary (production). Both round-trip every
//!   float bitwise; SHED/error payloads are always JSON.
//! * [`admission`] — per-tenant token buckets; an empty bucket sheds
//!   with a computed retry-after hint.
//! * [`server`] — the accept loop, per-connection handlers, the three
//!   shedding gates (connection cap, tenant bucket, queue
//!   backpressure), and graceful drain.
//! * [`client`] — the blocking client the loadgen, CLI and tests use.
//! * [`loadgen`] — deterministic multi-tenant load with a latency /
//!   shed-rate report.
//!
//! `sqlsq listen` and `sqlsq loadgen` are the CLI doors; the
//! `serve_load` bench drives a server in-process and emits
//! `BENCH_serve_load.json`.

pub mod admission;
pub mod client;
pub mod frame;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use admission::TenantBuckets;
pub use client::{Client, WireReply};
pub use frame::{read_frame, write_frame, Codec, Frame, FrameKind, ReadOutcome};
pub use loadgen::{run as run_load, LoadReport, LoadSpec};
pub use protocol::{
    decode_error, decode_request, decode_result, decode_shed, encode_error, encode_request,
    encode_result, encode_shed, WireRequest, WireResult,
};
pub use server::{ServeConfig, Server};
