//! The socket server: accept loop, per-connection handlers, admission
//! control, and graceful drain over a [`Coordinator`].
//!
//! # Lifecycle
//!
//! ```text
//! accept ──▶ admit ──▶ queue ──▶ respond          (per request)
//!   │          │          │         │
//!   │ conn cap │ tenant   │ try_submit_request_as │ Result / Shed /
//!   │ → SHED   │ bucket   │ Saturated → SHED      │ Error frame, same
//!   │          │ → SHED   │ Shutdown  → close     │ codec as request
//!   ▼
//! drain: stop accepting ──▶ close queues ──▶ flush in-flight ──▶ join
//! ```
//!
//! One OS thread per connection (bounded by `max_conns`); each handler
//! loops `read_frame → decode → admit → submit → respond`. The handler
//! blocks on the job's result channel — per-connection pipelining is
//! one-at-a-time by design, matching the blocking [`super::Client`];
//! parallelism comes from multiple connections.
//!
//! # Admission control and shedding
//!
//! Three gates, cheapest first, each mapping pressure to an explicit
//! response rather than an open-ended stall:
//!
//! 1. **Connection cap** — over `max_conns`, the accept loop writes one
//!    SHED frame and closes immediately.
//! 2. **Tenant bucket** — [`super::TenantBuckets`] (off by default);
//!    an empty bucket sheds with the bucket's computed retry-after.
//! 3. **Queue backpressure** — [`Coordinator::try_submit_request_as`]
//!    returning [`Error::Saturated`] sheds with the configured
//!    `shed_retry_ms` hint. [`Error::Shutdown`] instead closes the
//!    connection: the coordinator is draining and no retry against this
//!    server can succeed.
//!
//! SHED and error payloads are always JSON ([`Codec::Json`] on the
//! frame), whatever codec the request used — they are tiny and must
//! stay readable in a packet dump.
//!
//! # Graceful drain
//!
//! [`Server::shutdown`] stops the accept loop, closes the coordinator
//! queues via [`Coordinator::begin_drain`] (new submits refuse with
//! [`Error::Shutdown`]; accepted jobs keep running), joins every
//! handler once its in-flight result has been flushed, then joins the
//! workers and returns the final [`Snapshot`]. Every job that was
//! accepted before the drain gets its response.

use super::admission::TenantBuckets;
use super::frame::{read_frame, write_frame, Codec, Frame, FrameKind, ReadOutcome};
use super::protocol::{decode_request, encode_error, encode_result, encode_shed, WireResult};
use crate::coordinator::{Coordinator, Payload, Snapshot};
use crate::quant::QuantRequest;
use crate::{Error, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// How long a handler blocks in `read` before checking the drain flag.
const READ_TICK: Duration = Duration::from_millis(100);
/// Accept-loop poll interval when no connection is pending.
const ACCEPT_TICK: Duration = Duration::from_millis(5);

/// Network front-end configuration (the coordinator's own knobs ride in
/// [`crate::Config`]).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, `host:port`. Port 0 picks an ephemeral port
    /// (see [`Server::addr`]).
    pub addr: String,
    /// Connection cap; an accept beyond it is shed immediately.
    pub max_conns: usize,
    /// Per-tenant admission rate, tokens/second. `<= 0` disables
    /// tenant fairness (the default).
    pub tenant_rate: f64,
    /// Per-tenant burst capacity (floored at 1).
    pub tenant_burst: f64,
    /// Retry-after hint on queue-backpressure SHEDs, milliseconds.
    pub shed_retry_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            max_conns: 64,
            tenant_rate: 0.0,
            tenant_burst: 8.0,
            shed_retry_ms: 50,
        }
    }
}

/// Shared state between the accept loop and the handlers.
struct Shared {
    coord: Coordinator,
    buckets: TenantBuckets,
    stop: AtomicBool,
    conns: AtomicUsize,
    shed_retry_ms: u64,
}

/// Decrements the live-connection count when a handler exits by any
/// path, including a panic unwind.
struct ConnGuard<'a>(&'a AtomicUsize);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running socket server owning its [`Coordinator`]. Dropping the
/// handle without calling [`Server::shutdown`] aborts the accept loop
/// but skips the graceful join; call `shutdown` for a clean drain.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<thread::JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl Server {
    /// Bind `scfg.addr` and start serving `coord`. The server takes
    /// ownership of the coordinator; results, metrics and the final
    /// drain all flow through this handle.
    pub fn start(coord: Coordinator, scfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&scfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            coord,
            buckets: TenantBuckets::new(scfg.tenant_rate, scfg.tenant_burst),
            stop: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            shed_retry_ms: scfg.shed_retry_ms,
        });
        let handlers: Arc<Mutex<Vec<thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            let max_conns = scfg.max_conns.max(1);
            thread::Builder::new()
                .name("sqlsq-accept".into())
                .spawn(move || accept_loop(&listener, &shared, &handlers, max_conns))
                .map_err(Error::Io)?
        };
        Ok(Server { shared, addr, accept: Some(accept), handlers })
    }

    /// The bound address (resolves port 0 binds to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live metrics snapshot of the underlying coordinator.
    pub fn metrics(&self) -> Snapshot {
        self.shared.coord.metrics()
    }

    /// Graceful drain (see the module docs): stop accepting, close the
    /// queues, flush every in-flight job's response, join all threads,
    /// and return the final metrics snapshot.
    pub fn shutdown(mut self) -> Snapshot {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Close the queues now so handlers blocked in `read` refuse new
        // work with `Shutdown` and exit at the next READ_TICK, while
        // workers finish everything already accepted.
        self.shared.coord.begin_drain();
        let joins = {
            let mut g = self.handlers.lock().unwrap_or_else(|e| e.into_inner());
            std::mem::take(&mut *g)
        };
        for h in joins {
            let _ = h.join();
        }
        // All clones are gone once the accept loop and the handlers have
        // been joined, so the unwrap cannot fail; `shutdown` then joins
        // the (already idle) workers for the final snapshot.
        match Arc::try_unwrap(self.shared) {
            Ok(s) => s.coord.shutdown(),
            Err(arc) => arc.coord.metrics(),
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    handlers: &Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    max_conns: usize,
) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if shared.conns.load(Ordering::SeqCst) >= max_conns {
                    // Over capacity: one SHED frame, then hang up. The
                    // stream is still nonblocking-inherited on some
                    // platforms; a best-effort write is all we owe.
                    let _ = stream.set_nonblocking(false);
                    let mut f = Frame::new(
                        FrameKind::Shed,
                        Codec::Json,
                        encode_shed(shared.shed_retry_ms, "connection limit reached"),
                    );
                    f.tenant = None;
                    let _ = write_frame(&mut stream, &f);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let conn_shared = Arc::clone(shared);
                let spawned = thread::Builder::new()
                    .name("sqlsq-conn".into())
                    .spawn(move || {
                        let _guard = ConnGuard(&conn_shared.conns);
                        handle_conn(stream, &conn_shared);
                    });
                match spawned {
                    Ok(h) => {
                        let mut g = handlers.lock().unwrap_or_else(|e| e.into_inner());
                        g.retain(|h| !h.is_finished());
                        g.push(h);
                    }
                    Err(_) => {
                        // Spawn failed; the guard never ran, undo here.
                        shared.conns.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_TICK);
            }
            Err(_) => thread::sleep(ACCEPT_TICK),
        }
    }
}

/// Per-connection handler: frames in, frames out, until EOF, a protocol
/// violation, a write failure, or drain.
fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let _ = stream.set_nodelay(true);
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::IdleTimeout) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Ok(ReadOutcome::Eof) => break,
            Err(Error::InvalidInput(msg)) => {
                // Protocol violation: the stream cannot be resynced.
                // Best-effort error frame, then close.
                let f = Frame::new(FrameKind::Error, Codec::Json, encode_error(&msg));
                let _ = write_frame(&mut stream, &f);
                break;
            }
            Err(_) => break, // truncated frame / hard I/O error
        };
        let (reply, close_after) = match frame.kind {
            FrameKind::Ping => (Frame::new(FrameKind::Pong, frame.codec, Vec::new()), false),
            FrameKind::Quant => handle_quant(shared, &frame),
            // A client sending response kinds is violating the protocol.
            FrameKind::Result | FrameKind::Shed | FrameKind::Error | FrameKind::Pong => (
                Frame::new(
                    FrameKind::Error,
                    Codec::Json,
                    encode_error("protocol violation: response kind from client"),
                ),
                true,
            ),
        };
        if write_frame(&mut stream, &reply).is_err() || close_after {
            break;
        }
    }
}

/// Serve one `Quant` frame: decode, admit, submit, wait, encode.
/// Returns the reply and whether the connection must close afterwards
/// (true only for the permanent [`Error::Shutdown`] refusal).
fn handle_quant(shared: &Shared, frame: &Frame) -> (Frame, bool) {
    let codec = frame.codec;
    let wire = match decode_request(&frame.payload, codec) {
        Ok(w) => w,
        Err(e) => {
            // Request-level error: the connection survives.
            return (
                Frame::new(FrameKind::Error, Codec::Json, encode_error(&e.to_string())),
                false,
            );
        }
    };
    let tenant = frame.tenant.as_deref();
    if let Err(wait) = shared.buckets.try_acquire(tenant.unwrap_or("")) {
        let ms = (wait.as_millis() as u64).max(1);
        return (
            Frame::new(FrameKind::Shed, Codec::Json, encode_shed(ms, "tenant rate limit")),
            false,
        );
    }
    let mut req = match &wire.payload {
        Payload::F64(v) => QuantRequest::shared(Arc::clone(v)),
        Payload::F32(v) => QuantRequest::shared_f32(Arc::clone(v)),
    }
    .method(wire.method)
    .options(wire.opts);
    if let Some(w) = wire.weights {
        // Malformed weights (length mismatch, NaN, negative, zero-sum)
        // surface as an admission-time InvalidInput below — a
        // request-level error frame; the connection survives.
        req = req.weights(w);
    }
    match shared.coord.try_submit_request_as(req, tenant) {
        Ok((id, rx)) => match rx.recv() {
            Ok(result) => match result.outcome {
                Ok(out) => {
                    let cb = out.codebook();
                    let res = WireResult {
                        id,
                        served_by: result.served_by.label().to_string(),
                        lane: out.precision(),
                        levels_requested: out.levels_requested(),
                        l2_loss: out.l2_loss(),
                        levels: cb.levels,
                        indices: cb.indices,
                    };
                    (Frame::new(FrameKind::Result, codec, encode_result(&res, codec)), false)
                }
                Err(msg) => {
                    (Frame::new(FrameKind::Error, Codec::Json, encode_error(&msg)), false)
                }
            },
            Err(_) => (
                Frame::new(
                    FrameKind::Error,
                    Codec::Json,
                    encode_error("result channel dropped before completion"),
                ),
                false,
            ),
        },
        Err(Error::Saturated(m)) => (
            Frame::new(FrameKind::Shed, Codec::Json, encode_shed(shared.shed_retry_ms, &m)),
            false,
        ),
        // Permanent: the coordinator is draining. Report once, hang up.
        Err(Error::Shutdown(m)) => (
            Frame::new(
                FrameKind::Error,
                Codec::Json,
                encode_error(&format!("shutting down: {m}")),
            ),
            true,
        ),
        Err(e) => {
            (Frame::new(FrameKind::Error, Codec::Json, encode_error(&e.to_string())), false)
        }
    }
}
