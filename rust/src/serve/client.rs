//! Blocking client for the serve protocol: one connection, one
//! request/response in flight at a time (the server's per-connection
//! contract). Run several clients for parallel load — that is what
//! [`super::loadgen`] does.

use super::frame::{read_frame, write_frame, Codec, Frame, FrameKind, ReadOutcome};
use super::protocol::{
    decode_error, decode_result, decode_shed, encode_request, WireRequest, WireResult,
};
use crate::{Error, Result};
use std::net::{TcpStream, ToSocketAddrs};

/// What came back for one request: the three response modes a caller
/// must handle distinctly.
#[derive(Debug, Clone)]
pub enum WireReply {
    /// The job completed; here is its codebook.
    Result(WireResult),
    /// The server shed the request (admission or queue backpressure).
    /// Retry after the hint — against this server for queue sheds, or
    /// elsewhere if sheds persist.
    Shed {
        /// Server-suggested backoff before retrying, milliseconds.
        retry_after_ms: u64,
        /// Human-readable shed cause ("queue full", "tenant rate
        /// limit", "connection limit reached").
        reason: String,
    },
    /// The request failed (bad payload, solver failure, or the server
    /// is draining — draining servers also close the connection).
    Error(String),
}

/// A blocking connection to a [`super::Server`].
pub struct Client {
    stream: TcpStream,
    codec: Codec,
    tenant: Option<String>,
}

impl Client {
    /// Connect to `addr`, speaking `codec`, optionally stamping every
    /// frame with a tenant id (≤ 64 bytes, see
    /// [`super::frame::MAX_TENANT`]).
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        codec: Codec,
        tenant: Option<&str>,
    ) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, codec, tenant: tenant.map(str::to_string) })
    }

    /// Round-trip a liveness probe.
    pub fn ping(&mut self) -> Result<()> {
        let mut f = Frame::new(FrameKind::Ping, self.codec, Vec::new());
        f.tenant = self.tenant.clone();
        write_frame(&mut self.stream, &f)?;
        match self.read_reply()? {
            (FrameKind::Pong, _, _) => Ok(()),
            (k, _, _) => Err(Error::InvalidInput(format!("expected Pong, got {k:?}"))),
        }
    }

    /// Submit one quantization request under this client's tenant and
    /// block for the reply.
    pub fn quant(&mut self, req: &WireRequest) -> Result<WireReply> {
        let tenant = self.tenant.clone();
        self.quant_as(tenant.as_deref(), req)
    }

    /// [`Client::quant`] with an explicit per-request tenant override
    /// (the tenant rides on each frame, not the connection).
    pub fn quant_as(&mut self, tenant: Option<&str>, req: &WireRequest) -> Result<WireReply> {
        let mut f =
            Frame::new(FrameKind::Quant, self.codec, encode_request(req, self.codec));
        f.tenant = tenant.map(str::to_string);
        write_frame(&mut self.stream, &f)?;
        let (kind, codec, payload) = self.read_reply()?;
        match kind {
            FrameKind::Result => Ok(WireReply::Result(decode_result(&payload, codec)?)),
            FrameKind::Shed => {
                let (retry_after_ms, reason) = decode_shed(&payload)?;
                Ok(WireReply::Shed { retry_after_ms, reason })
            }
            FrameKind::Error => Ok(WireReply::Error(decode_error(&payload)?)),
            k => Err(Error::InvalidInput(format!("unexpected reply kind {k:?}"))),
        }
    }

    fn read_reply(&mut self) -> Result<(FrameKind, Codec, Vec<u8>)> {
        match read_frame(&mut self.stream)? {
            ReadOutcome::Frame(f) => Ok((f.kind, f.codec, f.payload)),
            // A clean EOF mid-conversation means the server hung up —
            // drain, connection-limit shed, or a protocol violation on
            // our side.
            ReadOutcome::Eof | ReadOutcome::IdleTimeout => {
                Err(Error::Shutdown("server closed connection".into()))
            }
        }
    }
}
