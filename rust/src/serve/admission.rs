//! Per-tenant admission control: token buckets keyed by the tenant id
//! carried in the frame header.
//!
//! Each tenant owns an independent bucket holding up to `burst` tokens,
//! refilled continuously at `rate` tokens/second. Admitting a request
//! costs one token; an empty bucket yields a SHED with a computed
//! retry-after hint — the time until one full token accrues. This is
//! fairness **before** the shared queue: a flooding tenant drains only
//! its own bucket, so a polite tenant's requests keep flowing even while
//! the flooder is being shed.
//!
//! `rate <= 0` disables limiting entirely (every acquire succeeds),
//! which is the default for [`super::ServeConfig`].
//!
//! Buckets are created lazily on first sight of a tenant id; requests
//! with no tenant header share the `""` bucket. State is a single
//! mutex-guarded map — acquisition is two float ops under the lock, so
//! contention is negligible next to a quantization solve.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One tenant's bucket: current balance and when it was last refilled.
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Lazily-populated per-tenant token buckets (see the module docs).
pub struct TenantBuckets {
    rate: f64,
    burst: f64,
    state: Mutex<HashMap<String, Bucket>>,
}

impl TenantBuckets {
    /// Build a bucket set refilling at `rate` tokens/second with
    /// capacity `burst` (floored at 1.0 so a fresh bucket always admits
    /// at least one request). `rate <= 0` means unlimited.
    pub fn new(rate: f64, burst: f64) -> TenantBuckets {
        TenantBuckets { rate, burst: burst.max(1.0), state: Mutex::new(HashMap::new()) }
    }

    /// Whether limiting is active at all.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Try to admit one request for `tenant`. `Ok(())` admits;
    /// `Err(wait)` sheds, with `wait` the time until a full token will
    /// have accrued (the retry-after hint for the SHED frame).
    pub fn try_acquire(&self, tenant: &str) -> std::result::Result<(), Duration> {
        if !self.enabled() {
            return Ok(());
        }
        let now = Instant::now();
        let mut state = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let b = state
            .entry(tenant.to_string())
            .or_insert_with(|| Bucket { tokens: self.burst, last: now });
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * self.rate).min(self.burst);
        b.last = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            let wait = (1.0 - b.tokens) / self.rate;
            Err(Duration::from_secs_f64(wait.clamp(0.001, 3600.0)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_unlimited() {
        let b = TenantBuckets::new(0.0, 8.0);
        assert!(!b.enabled());
        for _ in 0..10_000 {
            assert!(b.try_acquire("anyone").is_ok());
        }
    }

    #[test]
    fn burst_is_honored_then_empty_bucket_sheds_with_a_real_hint() {
        // Refill so slow it cannot matter within the test's runtime.
        let b = TenantBuckets::new(0.001, 2.0);
        assert!(b.try_acquire("t").is_ok());
        assert!(b.try_acquire("t").is_ok());
        let wait = b.try_acquire("t").expect_err("third request must shed");
        // ~1 token / 0.001 tok/s = ~1000s, clamped to the 3600s ceiling.
        assert!(wait >= Duration::from_secs(500), "hint was {wait:?}");
        assert!(wait <= Duration::from_secs(3600));
    }

    #[test]
    fn buckets_are_independent_per_tenant() {
        let b = TenantBuckets::new(0.001, 1.0);
        assert!(b.try_acquire("flooder").is_ok());
        assert!(b.try_acquire("flooder").is_err(), "flooder is out of tokens");
        assert!(b.try_acquire("polite").is_ok(), "polite tenant is unaffected");
    }

    #[test]
    fn fast_refill_recovers_quickly() {
        let b = TenantBuckets::new(1e9, 1.0);
        for _ in 0..100 {
            // Any failed acquire would need a ~1ns wait; at 1e9 tok/s the
            // bucket refills between iterations, so every call admits.
            assert!(b.try_acquire("t").is_ok());
        }
    }

    #[test]
    fn burst_floor_admits_at_least_one() {
        let b = TenantBuckets::new(0.001, 0.0);
        assert!(b.try_acquire("t").is_ok(), "burst is floored at 1.0");
        assert!(b.try_acquire("t").is_err());
    }
}
