//! Length-prefixed framing for the network serve protocol.
//!
//! Every message on a connection — request or response — is one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"sqlq"
//! 4       1     version (= 1)
//! 5       1     kind    (FrameKind: requests 0x01/0x02, responses 0x8x)
//! 6       1     codec   (0 = json, 1 = binary — how `payload` is encoded)
//! 7       1     tenant_len (0..=64; responses always send 0)
//! 8       4     payload_len, u32 little-endian (≤ 16 MiB)
//! 12      t     tenant id, UTF-8 (t = tenant_len)
//! 12+t    p     payload  (p = payload_len)
//! ```
//!
//! The header is fixed-size so a reader can validate everything before
//! allocating: bad magic, unknown version/kind, an over-long tenant, or
//! an oversized payload are *protocol violations* ([`crate::Error::InvalidInput`])
//! — after one, the stream position is untrustworthy, so the peer sends a
//! best-effort error frame and closes. A payload that parses as a frame
//! but fails codec validation is a *request error*: the connection
//! survives and the error comes back in an [`FrameKind::Error`] response.
//!
//! SHED and error response payloads are always JSON regardless of the
//! request codec — they are tiny and must stay debuggable from a hex
//! dump (see [`super::protocol`]).

use crate::{Error, Result};
use std::io::{ErrorKind, Read, Write};

/// Frame magic: the four bytes `b"sqlq"`.
pub const MAGIC: [u8; 4] = *b"sqlq";
/// Protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Hard cap on one frame's payload (16 MiB) — an admission-control
/// backstop so a malicious length prefix cannot make the server allocate
/// unboundedly.
pub const MAX_PAYLOAD: usize = 16 << 20;
/// Hard cap on the tenant-id header field.
pub const MAX_TENANT: usize = 64;

/// Frame header size in bytes.
pub const HEADER_LEN: usize = 12;

/// What a frame carries. Request kinds have the high bit clear, response
/// kinds have it set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Request: quantize one vector (payload = wire request).
    Quant,
    /// Request: liveness probe (empty payload).
    Ping,
    /// Response: a completed quantization (payload = wire result).
    Result,
    /// Response: admission refused under load — retry later (payload =
    /// JSON `{"retry_after_ms": .., "reason": ".."}`).
    Shed,
    /// Response: request failed (payload = JSON `{"error": ".."}`).
    Error,
    /// Response to [`FrameKind::Ping`] (empty payload).
    Pong,
}

impl FrameKind {
    /// Wire byte.
    pub fn as_u8(self) -> u8 {
        match self {
            FrameKind::Quant => 0x01,
            FrameKind::Ping => 0x02,
            FrameKind::Result => 0x81,
            FrameKind::Shed => 0x82,
            FrameKind::Error => 0x83,
            FrameKind::Pong => 0x84,
        }
    }

    /// Parse the wire byte.
    pub fn from_u8(b: u8) -> Result<FrameKind> {
        match b {
            0x01 => Ok(FrameKind::Quant),
            0x02 => Ok(FrameKind::Ping),
            0x81 => Ok(FrameKind::Result),
            0x82 => Ok(FrameKind::Shed),
            0x83 => Ok(FrameKind::Error),
            0x84 => Ok(FrameKind::Pong),
            _ => Err(Error::InvalidInput(format!("frame: unknown kind byte 0x{b:02x}"))),
        }
    }
}

/// How a frame's payload is encoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// The jsonio JSON forms — human-readable, for debugging.
    #[default]
    Json,
    /// Compact little-endian binary — the production path.
    Binary,
}

impl Codec {
    /// Wire byte.
    pub fn as_u8(self) -> u8 {
        match self {
            Codec::Json => 0,
            Codec::Binary => 1,
        }
    }

    /// Parse the wire byte.
    pub fn from_u8(b: u8) -> Result<Codec> {
        match b {
            0 => Ok(Codec::Json),
            1 => Ok(Codec::Binary),
            _ => Err(Error::InvalidInput(format!("frame: unknown codec byte 0x{b:02x}"))),
        }
    }

    /// Parse the CLI string form.
    pub fn parse(s: &str) -> Result<Codec> {
        match s {
            "json" => Ok(Codec::Json),
            "binary" => Ok(Codec::Binary),
            _ => Err(Error::Config(format!("unknown codec '{s}' (json|binary)"))),
        }
    }

    /// Stable string id.
    pub fn id(self) -> &'static str {
        match self {
            Codec::Json => "json",
            Codec::Binary => "binary",
        }
    }
}

/// One parsed frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// What the payload is.
    pub kind: FrameKind,
    /// How the payload is encoded.
    pub codec: Codec,
    /// Request tenant id (responses carry `None`).
    pub tenant: Option<String>,
    /// The encoded payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// A request/response frame without a tenant header.
    pub fn new(kind: FrameKind, codec: Codec, payload: Vec<u8>) -> Frame {
        Frame { kind, codec, tenant: None, payload }
    }
}

/// What [`read_frame`] observed on the stream.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete frame arrived.
    Frame(Frame),
    /// The peer closed the connection cleanly (EOF on a frame boundary).
    Eof,
    /// A read timeout elapsed before the first header byte — the
    /// connection is idle, not broken. Only produced on sockets with a
    /// read timeout set; callers use it as a poll tick (e.g. to check a
    /// drain flag) and call again.
    IdleTimeout,
}

/// Serialize `frame` onto `w`. Errs ([`Error::InvalidInput`]) on frames
/// that violate the protocol limits rather than emitting garbage.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let tenant = frame.tenant.as_deref().unwrap_or("");
    if tenant.len() > MAX_TENANT {
        return Err(Error::InvalidInput(format!(
            "frame: tenant id is {} bytes, max {MAX_TENANT}",
            tenant.len()
        )));
    }
    if frame.payload.len() > MAX_PAYLOAD {
        return Err(Error::InvalidInput(format!(
            "frame: payload is {} bytes, max {MAX_PAYLOAD}",
            frame.payload.len()
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = frame.kind.as_u8();
    header[6] = frame.codec.as_u8();
    header[7] = tenant.len() as u8;
    header[8..12].copy_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(tenant.as_bytes())?;
    w.write_all(&frame.payload)?;
    w.flush()?;
    Ok(())
}

/// How a buffered read ended.
enum Fill {
    /// The buffer was filled completely.
    Full,
    /// Clean EOF before the first byte.
    Eof,
    /// Read timeout before the first byte (socket with a read timeout) —
    /// the stream is idle at a safe boundary.
    Idle,
}

/// Fill `buf` from `r`. EOF or a timeout *mid*-buffer is an I/O error
/// (truncated frame / stalled peer — the stream position is lost); both
/// are only benign before the first byte, where they become
/// [`Fill::Eof`] / [`Fill::Idle`].
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<Fill> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(Fill::Eof);
                }
                return Err(Error::Io(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "truncated frame",
                )));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if filled == 0 {
                    return Ok(Fill::Idle);
                }
                return Err(Error::Io(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "peer stalled mid-frame",
                )));
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(Fill::Full)
}

/// Read one frame. Distinguishes the three non-error stream states
/// ([`ReadOutcome`]); protocol violations (bad magic/version/kind/codec,
/// over-long tenant, oversized payload) are [`Error::InvalidInput`] and
/// truncation mid-frame is an I/O error — after either, the stream
/// cannot be resynchronized and should be closed.
pub fn read_frame<R: Read>(r: &mut R) -> Result<ReadOutcome> {
    let mut header = [0u8; HEADER_LEN];
    match read_exact_or_eof(r, &mut header)? {
        Fill::Eof => return Ok(ReadOutcome::Eof),
        Fill::Idle => return Ok(ReadOutcome::IdleTimeout),
        Fill::Full => {}
    }
    if header[0..4] != MAGIC {
        return Err(Error::InvalidInput("frame: bad magic".into()));
    }
    if header[4] != VERSION {
        return Err(Error::InvalidInput(format!(
            "frame: unsupported version {} (this build speaks {VERSION})",
            header[4]
        )));
    }
    let kind = FrameKind::from_u8(header[5])?;
    let codec = Codec::from_u8(header[6])?;
    let tenant_len = header[7] as usize;
    if tenant_len > MAX_TENANT {
        return Err(Error::InvalidInput(format!(
            "frame: tenant length {tenant_len} exceeds max {MAX_TENANT}"
        )));
    }
    let payload_len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if payload_len > MAX_PAYLOAD {
        return Err(Error::InvalidInput(format!(
            "frame: payload length {payload_len} exceeds max {MAX_PAYLOAD}"
        )));
    }
    // Past the header, EOF/idle at "the first byte" of the body is still
    // mid-frame: truncation, not a clean boundary.
    let read_body = |r: &mut R, buf: &mut [u8]| -> Result<()> {
        match read_exact_or_eof(r, buf)? {
            Fill::Full => Ok(()),
            Fill::Eof | Fill::Idle => Err(Error::Io(std::io::Error::new(
                ErrorKind::UnexpectedEof,
                "truncated frame body",
            ))),
        }
    };
    let mut tenant_bytes = vec![0u8; tenant_len];
    read_body(r, &mut tenant_bytes)?;
    let tenant = if tenant_len == 0 {
        None
    } else {
        Some(
            String::from_utf8(tenant_bytes)
                .map_err(|_| Error::InvalidInput("frame: tenant id is not UTF-8".into()))?,
        )
    };
    let mut payload = vec![0u8; payload_len];
    read_body(r, &mut payload)?;
    Ok(ReadOutcome::Frame(Frame { kind, codec, tenant, payload }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        match read_frame(&mut buf.as_slice()).unwrap() {
            ReadOutcome::Frame(f) => f,
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip_all_kinds_and_codecs() {
        for kind in [
            FrameKind::Quant,
            FrameKind::Ping,
            FrameKind::Result,
            FrameKind::Shed,
            FrameKind::Error,
            FrameKind::Pong,
        ] {
            for codec in [Codec::Json, Codec::Binary] {
                let f = Frame {
                    kind,
                    codec,
                    tenant: Some("tenant-a".into()),
                    payload: vec![1, 2, 3, 255, 0],
                };
                assert_eq!(roundtrip(&f), f);
            }
        }
        // Empty payload, no tenant.
        let f = Frame::new(FrameKind::Ping, Codec::Json, vec![]);
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn eof_at_boundary_is_clean_but_truncation_is_an_error() {
        assert!(matches!(read_frame(&mut [].as_slice()).unwrap(), ReadOutcome::Eof));
        let mut buf = Vec::new();
        let f = Frame::new(FrameKind::Quant, Codec::Binary, vec![9; 32]);
        write_frame(&mut buf, &f).unwrap();
        // Cut at every prefix: a frame boundary is clean EOF; anything
        // else is a truncation error — never a bogus frame, never a
        // panic.
        for cut in 1..buf.len() {
            match read_frame(&mut buf[..cut].as_slice()) {
                Err(Error::Io(e)) => assert_eq!(e.kind(), ErrorKind::UnexpectedEof, "cut={cut}"),
                other => panic!("cut={cut}: expected truncation error, got {other:?}"),
            }
        }
    }

    #[test]
    fn protocol_violations_are_rejected() {
        let mut good = Vec::new();
        write_frame(&mut good, &Frame::new(FrameKind::Ping, Codec::Json, vec![])).unwrap();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'x';
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
        // Unsupported version.
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
        // Unknown kind.
        let mut bad = good.clone();
        bad[5] = 0x7f;
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
        // Unknown codec.
        let mut bad = good.clone();
        bad[6] = 7;
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
        // Over-long tenant claim.
        let mut bad = good.clone();
        bad[7] = 200;
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
        // Oversized payload claim: rejected from the header alone —
        // nothing that large is ever allocated or read.
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(read_frame(&mut bad.as_slice()), Err(Error::InvalidInput(_))));
    }

    #[test]
    fn writer_enforces_the_same_limits() {
        let long_tenant = Frame {
            kind: FrameKind::Quant,
            codec: Codec::Json,
            tenant: Some("t".repeat(MAX_TENANT + 1)),
            payload: vec![],
        };
        assert!(write_frame(&mut Vec::new(), &long_tenant).is_err());
    }

    #[test]
    fn two_frames_back_to_back_parse_in_order() {
        let a = Frame::new(FrameKind::Quant, Codec::Binary, vec![1]);
        let b = Frame::new(FrameKind::Result, Codec::Json, vec![2, 3]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut r = buf.as_slice();
        assert!(matches!(read_frame(&mut r).unwrap(), ReadOutcome::Frame(f) if f == a));
        assert!(matches!(read_frame(&mut r).unwrap(), ReadOutcome::Frame(f) if f == b));
        assert!(matches!(read_frame(&mut r).unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn kind_and_codec_bytes_are_stable() {
        // Wire compatibility pin: these bytes are the protocol.
        for (kind, byte) in [
            (FrameKind::Quant, 0x01),
            (FrameKind::Ping, 0x02),
            (FrameKind::Result, 0x81),
            (FrameKind::Shed, 0x82),
            (FrameKind::Error, 0x83),
            (FrameKind::Pong, 0x84),
        ] {
            assert_eq!(kind.as_u8(), byte);
            assert_eq!(FrameKind::from_u8(byte).unwrap(), kind);
        }
        assert_eq!(Codec::Json.as_u8(), 0);
        assert_eq!(Codec::Binary.as_u8(), 1);
        assert_eq!(Codec::parse("json").unwrap(), Codec::Json);
        assert_eq!(Codec::parse("binary").unwrap(), Codec::Binary);
        assert!(Codec::parse("protobuf").is_err());
        assert_eq!(Codec::Binary.id(), "binary");
    }
}
