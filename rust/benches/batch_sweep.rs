//! §Perf: one-shot vs staged λ-sweep throughput (the ISSUE-1 acceptance
//! bench). Compares 16 independent `quantize` calls on a 10k-element
//! vector against one `PreparedInput` + a warm-started 16-point
//! `quantize_sweep`, `quantize_batch` against a serial loop, (ISSUE-2)
//! the f32 lane against the f64 lane on the same sweep workload — both
//! throughput and total-information-loss delta — and (ISSUE-3) the
//! runtime lane's drained-batch service serial vs fanned across
//! `runtime_fanout` sub-lanes (ShadowBackend: runtime semantics, no
//! artifacts), and (ISSUE-6) the CD epoch loops before/after the
//! kernel-layer restructure — in-bench copies of the seed's pre-kernel
//! structured and dense inner loops raced against the current
//! `lasso::solve` / `lasso::solve_dense` at fixed epoch budgets, and
//! (ISSUE-8) repeat-heavy coordinator traffic with the serve-path result
//! cache off vs on (hit rate, bytes saved, hit-path vs solve-path
//! medians), and (ISSUE-10) an `nn-weights` scenario — an NN-like weight
//! vector with importance concentrated on its salient tail, quantized
//! with and without per-element weights, comparing both runtime and the
//! weighted objective Σ wᵢ(xᵢ−qᵢ)² the weighted solve minimizes. Emits a
//! `BENCH_batch_sweep.json` baseline (median seconds + speedups) for the
//! perf trajectory.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::config::{CachePolicy, Config, Engine};
use sqlsq::coordinator::server::serve_batch_runtime;
use sqlsq::coordinator::{Coordinator, Job, Metrics, Payload, Router};
use sqlsq::data::rng::Pcg32;
use sqlsq::eval::workloads::lambda_grid;
use sqlsq::jsonio::Json;
use sqlsq::quant::{
    self, lasso, vmatrix::VBasis, PreparedInput, PreparedInputF32, QuantMethod, QuantOptions,
};
use sqlsq::runtime::{BackendKind, ShadowBackend};

fn raster_vector(n: usize, levels: f64, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.uniform(0.0, 1.0) * levels).round() / levels).collect()
}

fn sorted_values(m: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    v
}

// ---------------------------------------------------------------------
// "Before" copies of the seed's pre-kernel CD epoch loops, kept here so
// the restructure stays raceable end-to-end: indexed residual rebuild,
// per-coordinate col_norm_sq recompute, open-coded soft threshold, and
// (dense) the separate suffix + correction loops that `shrink_axpy`
// fused. No early stop — both sides run the exact epoch budget.
// ---------------------------------------------------------------------

#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn cd_structured_reference(
    basis: &VBasis<f64>,
    w: &[f64],
    lambda1: f64,
    epochs: usize,
) -> Vec<f64> {
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = vec![0.0f64; m];
    let mut rec = vec![0.0f64; m];
    let mut r = vec![0.0f64; m];
    for _ in 0..epochs {
        basis.apply_into(&alpha, &mut rec);
        for i in 0..m {
            r[i] = w[i] - rec[i];
        }
        let mut s = 0.0f64;
        for j in (0..m).rev() {
            s += r[j];
            let dj = d[j];
            if dj == 0.0 {
                continue;
            }
            let cj = basis.col_norm_sq(j);
            let rho = dj * s + cj * alpha[j];
            let shrunk = if rho > lambda1 {
                rho - lambda1
            } else if rho < -lambda1 {
                rho + lambda1
            } else {
                0.0
            };
            let new = shrunk / cj;
            let delta = new - alpha[j];
            if delta != 0.0 {
                alpha[j] = new;
                s -= (m - j) as f64 * dj * delta;
            }
        }
    }
    alpha
}

#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn cd_dense_reference(basis: &VBasis<f64>, w: &[f64], lambda1: f64, epochs: usize) -> Vec<f64> {
    let m = basis.m();
    let d = basis.diffs();
    let mut alpha = vec![0.0f64; m];
    let mut r = Vec::with_capacity(m);
    for (i, wi) in w.iter().enumerate() {
        let mut acc = 0.0f64;
        for j in 0..=i {
            acc += d[j] * alpha[j];
        }
        r.push(*wi - acc);
    }
    for _ in 0..epochs {
        for j in 0..m {
            let dj = d[j];
            if dj == 0.0 {
                continue;
            }
            let cj = basis.col_norm_sq(j);
            let mut suffix = 0.0f64;
            for ri in &r[j..] {
                suffix += *ri;
            }
            let rho = suffix * dj + cj * alpha[j];
            let shrunk = if rho > lambda1 {
                rho - lambda1
            } else if rho < -lambda1 {
                rho + lambda1
            } else {
                0.0
            };
            let new = shrunk / cj;
            let delta = new - alpha[j];
            if delta != 0.0 {
                alpha[j] = new;
                for ri in &mut r[j..] {
                    *ri -= dj * delta;
                }
            }
        }
    }
    alpha
}

fn main() {
    let data = raster_vector(10_000, 768.0, 11);
    let lambdas = lambda_grid(1e-4, 1e-1, 16).unwrap();
    let opts = QuantOptions::default();
    let method = QuantMethod::L1LeastSquare;

    let mut suite = Suite::with_config("Batch sweep", active_config());

    let one_shot_s = suite
        .case("one_shot_x16/n=10k", || {
            for &lambda in &lambdas {
                black_box(
                    quant::quantize(
                        &data,
                        method,
                        &QuantOptions { lambda1: lambda, ..opts.clone() },
                    )
                    .unwrap(),
                );
            }
        })
        .median;

    let sweep_s = suite
        .case("prepared_warm_sweep_x16/n=10k", || {
            let prep = PreparedInput::new(&data).unwrap();
            black_box(quant::quantize_sweep(&prep, method, &lambdas, &opts).unwrap());
        })
        .median;

    // Cold sweep isolates the prepare-amortization share of the win.
    let cold_sweep_s = suite
        .case("prepared_cold_sweep_x16/n=10k", || {
            let prep = PreparedInput::new(&data).unwrap();
            black_box(
                quant::quantize_sweep_with(&prep, method, &lambdas, &opts, false).unwrap(),
            );
        })
        .median;

    // f32 lane vs f64 lane on the same sweep workload: prepare + 16 warm
    // solves per iteration in both cases. The one-time f64→f32 narrowing
    // is deliberately OUTSIDE the timed case — the lane's intended clients
    // (NN weights) hold f32 data natively, so narrowing is not part of the
    // steady-state cost being compared.
    let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let f32_sweep_s = suite
        .case("prepared_warm_sweep_f32_x16/n=10k", || {
            let prep = PreparedInputF32::new(&data32).unwrap();
            black_box(quant::quantize_sweep_f32(&prep, method, &lambdas, &opts).unwrap());
        })
        .median;

    // Info-loss delta between the lanes, measured outside the timed loop:
    // total l2 loss across the λ grid (per-point losses near λ→0 are ~0 in
    // both lanes, so the total is the stable comparison).
    let outs64 = {
        let prep = PreparedInput::new(&data).unwrap();
        quant::quantize_sweep(&prep, method, &lambdas, &opts).unwrap()
    };
    let outs32 = {
        let prep = PreparedInputF32::new(&data32).unwrap();
        quant::quantize_sweep_f32(&prep, method, &lambdas, &opts).unwrap()
    };
    let f64_loss_total: f64 = outs64.iter().map(|o| o.l2_loss).sum();
    let f32_loss_total: f64 = outs32.iter().map(|o| o.l2_loss).sum();
    let f32_rel_loss_delta = (f32_loss_total - f64_loss_total).abs() / f64_loss_total.max(1e-12);

    // Batch fan-out vs a serial loop over 16 independent vectors.
    let inputs: Vec<Vec<f64>> = (0..16).map(|i| raster_vector(2000, 256.0, 100 + i)).collect();
    let batch_opts = QuantOptions { target_values: 16, ..Default::default() };
    let serial_s = suite
        .case("serial_loop_x16/n=2k/cluster_ls", || {
            for w in &inputs {
                black_box(quant::quantize(w, QuantMethod::ClusterLs, &batch_opts).unwrap());
            }
        })
        .median;
    let batch_s = suite
        .case("quantize_batch_x16/n=2k/cluster_ls", || {
            black_box(quant::quantize_batch(&inputs, QuantMethod::ClusterLs, &batch_opts));
        })
        .median;

    // Runtime-lane batch service: the same 16-job runtime-capable burst
    // through serve_batch_runtime, serial vs fanned. The shadow backend
    // replays the artifact kernels (f32, padding, epochs-per-call), so
    // this measures exactly the lane-level parallelism ISSUE-3 added.
    let rt_router = Router::new(
        Engine::Auto,
        std::path::Path::new("artifacts"),
        BackendKind::Shadow,
    )
    .expect("shadow router");
    let rt_inputs: Vec<(Vec<f64>, QuantMethod)> = (0..16)
        .map(|i| {
            let method = [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::Gmm]
                [i % 3];
            (raster_vector(2000, 512.0, 300 + i as u64), method)
        })
        .collect();
    let rt_opts = QuantOptions { lambda1: 0.01, target_values: 16, ..Default::default() };
    let run_runtime_batch = |fanout: usize| {
        let metrics = Metrics::new();
        let mut jobs = Vec::with_capacity(rt_inputs.len());
        let mut rxs = Vec::with_capacity(rt_inputs.len());
        for (i, (data, method)) in rt_inputs.iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel();
            jobs.push(Job {
                id: i as u64 + 1,
                data: Payload::F64(data.clone().into()),
                method: *method,
                opts: rt_opts.clone(),
                weights: None,
                submitted: std::time::Instant::now(),
                respond: tx,
                cache: None,
            });
            rxs.push(rx);
        }
        let mut backend = ShadowBackend::new();
        serve_batch_runtime(&mut backend, &rt_router, &metrics, jobs, fanout);
        for rx in rxs {
            black_box(rx.recv().expect("runtime bench job lost"));
        }
    };
    let rt_serial_s = suite
        .case("runtime_batch_serial_x16/n=2k", || run_runtime_batch(1))
        .median;
    let rt_fanout = 4usize;
    let rt_fanout_s = suite
        .case("runtime_batch_fanout4_x16/n=2k", || run_runtime_batch(rt_fanout))
        .median;

    // Serve-path result cache (ISSUE-8): identical repeat-heavy traffic
    // — 64 submits cycling over a pool of 8 distinct payloads — through
    // a cache-off coordinator (every submit solves) and a cache-on one
    // (the pool's first lap misses; every later submit is an exact
    // fingerprint hit served without entering a queue). The coordinators
    // persist across timing iterations, so the cache-on median measures
    // the steady-state hit path.
    let cache_pool: Vec<Vec<f64>> =
        (0..8u64).map(|i| raster_vector(2000, 256.0, 500 + i)).collect();
    let cache_opts = QuantOptions { target_values: 8, ..Default::default() };
    let cache_cfg = |policy: CachePolicy| Config {
        workers: 2,
        queue_capacity: 128,
        max_batch: 8,
        batch_wait_us: 100,
        engine: Engine::Native,
        cache_policy: policy,
        ..Default::default()
    };
    let run_traffic = |coord: &Coordinator| {
        let mut rxs = Vec::with_capacity(64);
        for i in 0..64usize {
            let w = &cache_pool[i % cache_pool.len()];
            let (_, rx) =
                coord.submit(w.clone(), QuantMethod::KMeans, cache_opts.clone()).unwrap();
            rxs.push(rx);
        }
        for rx in rxs {
            black_box(rx.recv().expect("cache bench job lost"));
        }
    };
    let coord_off = Coordinator::start(cache_cfg(CachePolicy::Off)).unwrap();
    let cache_off_s = suite
        .case("coordinator_repeat_x64_cache_off/n=2k", || run_traffic(&coord_off))
        .median;
    coord_off.shutdown();
    let coord_on = Coordinator::start(cache_cfg(CachePolicy::Lru)).unwrap();
    let cache_on_s = suite
        .case("coordinator_repeat_x64_cache_on/n=2k", || run_traffic(&coord_on))
        .median;
    let cache_snap = coord_on.shutdown();

    // Importance-weighted quantization (ISSUE-10): an NN-like weight
    // vector (clustered values + noise, the matvec demo's workload) where
    // the salient high-magnitude tail carries 10x importance. KMeansExact
    // is DP-optimal for the weighted 1-D objective, so the weighted solve
    // can only match or beat the unweighted levels on weighted loss — the
    // gain below measures how much the weights actually move the
    // codebook on this data.
    let quick = std::env::var("SQLSQ_BENCH_QUICK").is_ok();
    let nn_n: usize = if quick { 512 } else { 2048 };
    let mut nn_rng = Pcg32::seeded(900);
    let nn_data: Vec<f64> = (0..nn_n)
        .map(|_| {
            let c = [-0.6, -0.2, 0.1, 0.45, 0.8][(nn_rng.next_u32() % 5) as usize];
            c + nn_rng.normal() * 0.03
        })
        .collect();
    let nn_weights: Vec<f64> =
        nn_data.iter().map(|&x| if x > 0.6 { 10.0 } else { 1.0 }).collect();
    let nn_opts = QuantOptions { target_values: 4, seed: 9, ..Default::default() };
    let run_nn = |weights: Option<Vec<f64>>| -> Vec<f64> {
        let mut req = quant::QuantRequest::vector(nn_data.clone())
            .method(QuantMethod::KMeansExact)
            .options(nn_opts.clone());
        if let Some(w) = weights {
            req = req.weights(w);
        }
        quant::Quantizer::new()
            .run(&req)
            .unwrap()
            .into_single()
            .unwrap()
            .materialize_f64()
    };
    let nn_unweighted_s = suite
        .case(&format!("nn_weights_unweighted_solve/n={nn_n}/kmeans_exact"), || {
            black_box(run_nn(None));
        })
        .median;
    let nn_weighted_s = suite
        .case(&format!("nn_weights_weighted_solve/n={nn_n}/kmeans_exact"), || {
            black_box(run_nn(Some(nn_weights.clone())));
        })
        .median;
    let weighted_loss = |q: &[f64]| -> f64 {
        nn_data
            .iter()
            .zip(q)
            .zip(&nn_weights)
            .map(|((x, q), w)| w * (x - q) * (x - q))
            .sum()
    };
    let weighted_loss_unweighted_solve = weighted_loss(&run_nn(None));
    let weighted_loss_weighted_solve = weighted_loss(&run_nn(Some(nn_weights.clone())));
    let weighted_gain =
        weighted_loss_unweighted_solve / weighted_loss_weighted_solve.max(1e-18);

    // CD epochs before/after the kernel-layer restructure (ISSUE-6): the
    // in-bench pre-kernel copies above vs the current solvers, fixed
    // epoch budget on both sides (tol 0, support_patience 0 — no early
    // stop), f64 lane (the bitwise-reference lane the restructure must
    // not change).
    let cd_epochs = 10usize;
    let cd_lambda = 0.02f64;
    let cd_cfg = lasso::LassoConfig {
        lambda1: cd_lambda,
        max_epochs: cd_epochs,
        tol: 0.0,
        support_patience: 0,
        ..Default::default()
    };
    let structured_ms: &[usize] = if quick { &[256, 1024] } else { &[1024, 4096] };
    let dense_m: usize = if quick { 256 } else { 1024 };
    let mut cd_rows: Vec<Json> = Vec::new();
    for &m in structured_ms {
        let v = sorted_values(m, 42 + m as u64);
        let basis = VBasis::new(&v);
        let ref_s = suite
            .case(&format!("cd_structured_reference/m={m}/{cd_epochs}ep"), || {
                black_box(cd_structured_reference(&basis, &v, cd_lambda, cd_epochs));
            })
            .median;
        let kern_s = suite
            .case(&format!("cd_structured_kernel/m={m}/{cd_epochs}ep"), || {
                black_box(lasso::solve(&basis, &v, &cd_cfg, None).unwrap());
            })
            .median;
        cd_rows.push(Json::obj(vec![
            ("path", Json::Str("structured".into())),
            ("m", Json::Num(m as f64)),
            ("epochs", Json::Num(cd_epochs as f64)),
            ("reference_median_s", Json::Num(ref_s)),
            ("kernel_median_s", Json::Num(kern_s)),
            ("speedup", Json::Num(ref_s / kern_s.max(1e-12))),
        ]));
    }
    {
        let m = dense_m;
        let v = sorted_values(m, 77);
        let basis = VBasis::new(&v);
        let ref_s = suite
            .case(&format!("cd_dense_reference/m={m}/{cd_epochs}ep"), || {
                black_box(cd_dense_reference(&basis, &v, cd_lambda, cd_epochs));
            })
            .median;
        let kern_s = suite
            .case(&format!("cd_dense_kernel/m={m}/{cd_epochs}ep"), || {
                black_box(lasso::solve_dense(&basis, &v, &cd_cfg, None).unwrap());
            })
            .median;
        cd_rows.push(Json::obj(vec![
            ("path", Json::Str("dense".into())),
            ("m", Json::Num(m as f64)),
            ("epochs", Json::Num(cd_epochs as f64)),
            ("reference_median_s", Json::Num(ref_s)),
            ("kernel_median_s", Json::Num(kern_s)),
            ("speedup", Json::Num(ref_s / kern_s.max(1e-12))),
        ]));
    }

    let sweep_speedup = one_shot_s / sweep_s.max(1e-12);
    let batch_speedup = serial_s / batch_s.max(1e-12);
    let runtime_batch_speedup = rt_serial_s / rt_fanout_s.max(1e-12);
    let f32_sweep_speedup = sweep_s / f32_sweep_s.max(1e-12);
    let cache_speedup = cache_off_s / cache_on_s.max(1e-12);
    println!("\nsweep speedup (one-shot / warm sweep)  : {sweep_speedup:.2}x");
    println!("batch speedup (serial / scoped fan-out): {batch_speedup:.2}x");
    println!(
        "runtime-batch speedup (serial / fanout {rt_fanout}): {runtime_batch_speedup:.2}x"
    );
    println!("f32 lane speedup (f64 sweep / f32 sweep): {f32_sweep_speedup:.2}x");
    println!(
        "result-cache speedup (repeat traffic, off / on): {cache_speedup:.2}x \
         (hit rate {:.2}, {} hits / {} misses, {} compact bytes saved)",
        cache_snap.cache_hit_rate,
        cache_snap.cache_hits,
        cache_snap.cache_misses,
        cache_snap.cache_bytes_saved
    );
    println!(
        "f32 lane info-loss delta (total over grid): {f32_rel_loss_delta:.3e} \
         (f64 {f64_loss_total:.6e} vs f32 {f32_loss_total:.6e})"
    );
    println!(
        "nn-weights weighted-objective gain (unweighted / weighted solve): \
         {weighted_gain:.3}x ({weighted_loss_unweighted_solve:.6e} vs \
         {weighted_loss_weighted_solve:.6e})"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("batch_sweep".into())),
        ("n", Json::Num(10_000.0)),
        ("lambda_points", Json::Num(lambdas.len() as f64)),
        ("one_shot_median_s", Json::Num(one_shot_s)),
        ("warm_sweep_median_s", Json::Num(sweep_s)),
        ("cold_sweep_median_s", Json::Num(cold_sweep_s)),
        ("sweep_speedup", Json::Num(sweep_speedup)),
        ("batch_serial_median_s", Json::Num(serial_s)),
        ("batch_parallel_median_s", Json::Num(batch_s)),
        ("batch_speedup", Json::Num(batch_speedup)),
        ("f32_sweep_median_s", Json::Num(f32_sweep_s)),
        ("f32_sweep_speedup", Json::Num(f32_sweep_speedup)),
        ("runtime_batch_serial_median_s", Json::Num(rt_serial_s)),
        ("runtime_batch_fanout_median_s", Json::Num(rt_fanout_s)),
        ("runtime_batch_fanout", Json::Num(rt_fanout as f64)),
        ("runtime_batch_speedup", Json::Num(runtime_batch_speedup)),
        ("cache_off_median_s", Json::Num(cache_off_s)),
        ("cache_on_median_s", Json::Num(cache_on_s)),
        ("cache_speedup", Json::Num(cache_speedup)),
        ("cache_hit_rate", Json::Num(cache_snap.cache_hit_rate)),
        ("cache_hits", Json::Num(cache_snap.cache_hits as f64)),
        ("cache_misses", Json::Num(cache_snap.cache_misses as f64)),
        ("cache_bytes_saved", Json::Num(cache_snap.cache_bytes_saved as f64)),
        ("cache_solve_saved_us", Json::Num(cache_snap.cache_solve_saved_us as f64)),
        ("f64_loss_total", Json::Num(f64_loss_total)),
        ("f32_loss_total", Json::Num(f32_loss_total)),
        ("f32_rel_loss_delta", Json::Num(f32_rel_loss_delta)),
        ("nn_weights_n", Json::Num(nn_n as f64)),
        ("nn_weights_unweighted_median_s", Json::Num(nn_unweighted_s)),
        ("nn_weights_weighted_median_s", Json::Num(nn_weighted_s)),
        ("weighted_loss_unweighted_solve", Json::Num(weighted_loss_unweighted_solve)),
        ("weighted_loss_weighted_solve", Json::Num(weighted_loss_weighted_solve)),
        ("weighted_gain", Json::Num(weighted_gain)),
        ("cd_epoch_series_quick", Json::Bool(quick)),
        ("cd_epoch_series", Json::Arr(cd_rows)),
    ]);
    std::fs::write("BENCH_batch_sweep.json", json.to_pretty()).expect("write baseline json");
    println!("[written BENCH_batch_sweep.json]");

    suite.write_csv(std::path::Path::new("reports")).ok();
}
