//! §Perf: one-shot vs staged λ-sweep throughput (the ISSUE-1 acceptance
//! bench). Compares 16 independent `quantize` calls on a 10k-element
//! vector against one `PreparedInput` + a warm-started 16-point
//! `quantize_sweep`, `quantize_batch` against a serial loop, (ISSUE-2)
//! the f32 lane against the f64 lane on the same sweep workload — both
//! throughput and total-information-loss delta — and (ISSUE-3) the
//! runtime lane's drained-batch service serial vs fanned across
//! `runtime_fanout` sub-lanes (ShadowBackend: runtime semantics, no
//! artifacts). Emits a `BENCH_batch_sweep.json` baseline (median
//! seconds + speedups) for the perf trajectory.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::config::Engine;
use sqlsq::coordinator::server::serve_batch_runtime;
use sqlsq::coordinator::{Job, Metrics, Payload, Router};
use sqlsq::data::rng::Pcg32;
use sqlsq::eval::workloads::lambda_grid;
use sqlsq::jsonio::Json;
use sqlsq::quant::{self, PreparedInput, PreparedInputF32, QuantMethod, QuantOptions};
use sqlsq::runtime::{BackendKind, ShadowBackend};

fn raster_vector(n: usize, levels: f64, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.uniform(0.0, 1.0) * levels).round() / levels).collect()
}

fn main() {
    let data = raster_vector(10_000, 768.0, 11);
    let lambdas = lambda_grid(1e-4, 1e-1, 16).unwrap();
    let opts = QuantOptions::default();
    let method = QuantMethod::L1LeastSquare;

    let mut suite = Suite::with_config("Batch sweep", active_config());

    let one_shot_s = suite
        .case("one_shot_x16/n=10k", || {
            for &lambda in &lambdas {
                black_box(
                    quant::quantize(
                        &data,
                        method,
                        &QuantOptions { lambda1: lambda, ..opts.clone() },
                    )
                    .unwrap(),
                );
            }
        })
        .median;

    let sweep_s = suite
        .case("prepared_warm_sweep_x16/n=10k", || {
            let prep = PreparedInput::new(&data).unwrap();
            black_box(quant::quantize_sweep(&prep, method, &lambdas, &opts).unwrap());
        })
        .median;

    // Cold sweep isolates the prepare-amortization share of the win.
    let cold_sweep_s = suite
        .case("prepared_cold_sweep_x16/n=10k", || {
            let prep = PreparedInput::new(&data).unwrap();
            black_box(
                quant::quantize_sweep_with(&prep, method, &lambdas, &opts, false).unwrap(),
            );
        })
        .median;

    // f32 lane vs f64 lane on the same sweep workload: prepare + 16 warm
    // solves per iteration in both cases. The one-time f64→f32 narrowing
    // is deliberately OUTSIDE the timed case — the lane's intended clients
    // (NN weights) hold f32 data natively, so narrowing is not part of the
    // steady-state cost being compared.
    let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let f32_sweep_s = suite
        .case("prepared_warm_sweep_f32_x16/n=10k", || {
            let prep = PreparedInputF32::new(&data32).unwrap();
            black_box(quant::quantize_sweep_f32(&prep, method, &lambdas, &opts).unwrap());
        })
        .median;

    // Info-loss delta between the lanes, measured outside the timed loop:
    // total l2 loss across the λ grid (per-point losses near λ→0 are ~0 in
    // both lanes, so the total is the stable comparison).
    let outs64 = {
        let prep = PreparedInput::new(&data).unwrap();
        quant::quantize_sweep(&prep, method, &lambdas, &opts).unwrap()
    };
    let outs32 = {
        let prep = PreparedInputF32::new(&data32).unwrap();
        quant::quantize_sweep_f32(&prep, method, &lambdas, &opts).unwrap()
    };
    let f64_loss_total: f64 = outs64.iter().map(|o| o.l2_loss).sum();
    let f32_loss_total: f64 = outs32.iter().map(|o| o.l2_loss).sum();
    let f32_rel_loss_delta = (f32_loss_total - f64_loss_total).abs() / f64_loss_total.max(1e-12);

    // Batch fan-out vs a serial loop over 16 independent vectors.
    let inputs: Vec<Vec<f64>> = (0..16).map(|i| raster_vector(2000, 256.0, 100 + i)).collect();
    let batch_opts = QuantOptions { target_values: 16, ..Default::default() };
    let serial_s = suite
        .case("serial_loop_x16/n=2k/cluster_ls", || {
            for w in &inputs {
                black_box(quant::quantize(w, QuantMethod::ClusterLs, &batch_opts).unwrap());
            }
        })
        .median;
    let batch_s = suite
        .case("quantize_batch_x16/n=2k/cluster_ls", || {
            black_box(quant::quantize_batch(&inputs, QuantMethod::ClusterLs, &batch_opts));
        })
        .median;

    // Runtime-lane batch service: the same 16-job runtime-capable burst
    // through serve_batch_runtime, serial vs fanned. The shadow backend
    // replays the artifact kernels (f32, padding, epochs-per-call), so
    // this measures exactly the lane-level parallelism ISSUE-3 added.
    let rt_router = Router::new(
        Engine::Auto,
        std::path::Path::new("artifacts"),
        BackendKind::Shadow,
    )
    .expect("shadow router");
    let rt_inputs: Vec<(Vec<f64>, QuantMethod)> = (0..16)
        .map(|i| {
            let method = [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::Gmm]
                [i % 3];
            (raster_vector(2000, 512.0, 300 + i as u64), method)
        })
        .collect();
    let rt_opts = QuantOptions { lambda1: 0.01, target_values: 16, ..Default::default() };
    let run_runtime_batch = |fanout: usize| {
        let metrics = Metrics::new();
        let mut jobs = Vec::with_capacity(rt_inputs.len());
        let mut rxs = Vec::with_capacity(rt_inputs.len());
        for (i, (data, method)) in rt_inputs.iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel();
            jobs.push(Job {
                id: i as u64 + 1,
                data: Payload::F64(data.clone().into()),
                method: *method,
                opts: rt_opts.clone(),
                submitted: std::time::Instant::now(),
                respond: tx,
            });
            rxs.push(rx);
        }
        let mut backend = ShadowBackend::new();
        serve_batch_runtime(&mut backend, &rt_router, &metrics, jobs, fanout);
        for rx in rxs {
            black_box(rx.recv().expect("runtime bench job lost"));
        }
    };
    let rt_serial_s = suite
        .case("runtime_batch_serial_x16/n=2k", || run_runtime_batch(1))
        .median;
    let rt_fanout = 4usize;
    let rt_fanout_s = suite
        .case("runtime_batch_fanout4_x16/n=2k", || run_runtime_batch(rt_fanout))
        .median;

    let sweep_speedup = one_shot_s / sweep_s.max(1e-12);
    let batch_speedup = serial_s / batch_s.max(1e-12);
    let runtime_batch_speedup = rt_serial_s / rt_fanout_s.max(1e-12);
    let f32_sweep_speedup = sweep_s / f32_sweep_s.max(1e-12);
    println!("\nsweep speedup (one-shot / warm sweep)  : {sweep_speedup:.2}x");
    println!("batch speedup (serial / scoped fan-out): {batch_speedup:.2}x");
    println!(
        "runtime-batch speedup (serial / fanout {rt_fanout}): {runtime_batch_speedup:.2}x"
    );
    println!("f32 lane speedup (f64 sweep / f32 sweep): {f32_sweep_speedup:.2}x");
    println!(
        "f32 lane info-loss delta (total over grid): {f32_rel_loss_delta:.3e} \
         (f64 {f64_loss_total:.6e} vs f32 {f32_loss_total:.6e})"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("batch_sweep".into())),
        ("n", Json::Num(10_000.0)),
        ("lambda_points", Json::Num(lambdas.len() as f64)),
        ("one_shot_median_s", Json::Num(one_shot_s)),
        ("warm_sweep_median_s", Json::Num(sweep_s)),
        ("cold_sweep_median_s", Json::Num(cold_sweep_s)),
        ("sweep_speedup", Json::Num(sweep_speedup)),
        ("batch_serial_median_s", Json::Num(serial_s)),
        ("batch_parallel_median_s", Json::Num(batch_s)),
        ("batch_speedup", Json::Num(batch_speedup)),
        ("f32_sweep_median_s", Json::Num(f32_sweep_s)),
        ("f32_sweep_speedup", Json::Num(f32_sweep_speedup)),
        ("runtime_batch_serial_median_s", Json::Num(rt_serial_s)),
        ("runtime_batch_fanout_median_s", Json::Num(rt_fanout_s)),
        ("runtime_batch_fanout", Json::Num(rt_fanout as f64)),
        ("runtime_batch_speedup", Json::Num(runtime_batch_speedup)),
        ("f64_loss_total", Json::Num(f64_loss_total)),
        ("f32_loss_total", Json::Num(f32_loss_total)),
        ("f32_rel_loss_delta", Json::Num(f32_rel_loss_delta)),
    ]);
    std::fs::write("BENCH_batch_sweep.json", json.to_pretty()).expect("write baseline json");
    println!("[written BENCH_batch_sweep.json]");

    suite.write_csv(std::path::Path::new("reports")).ok();
}
