//! Quantized-compute serving bench (ISSUE-7 acceptance): `QMatrix::matvec`
//! straight off the packed ⌈log₂k⌉-bit index planes, raced against
//!
//! * `decode_dense` — decode the codebook payload to a dense matrix and
//!   run the dense matvec, **per call** (what serving from the compact
//!   wire form cost before `QMatrix` existed), and
//! * `dense_pre` — the dense matvec on a pre-materialized matrix (the
//!   steady-state dense baseline; the packed path trades its gather
//!   arithmetic against moving 64 bits per entry).
//!
//! Emits `BENCH_qmatvec.json`: a quantized-vs-dense throughput series
//! over sizes × bit widths (both precision lanes), plus the residual
//! cascade's error-vs-cumulative-bits series. The acceptance criterion
//! reads `speedup_vs_decode > 1` at low bit widths for rows ≥ 4096.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::data::rng::Pcg32;
use sqlsq::jsonio::Json;
use sqlsq::linalg::matrix::Matrix;
use sqlsq::quant::tensor::Grouping;
use sqlsq::quant::{QMatrix, QuantMethod, QuantOptions};

/// Clustered NN-like weights, rounded to a coarse grid so the k-means
/// build stage stays cheap at bench sizes (the compute path under test
/// does not depend on how the levels were fit).
fn weights(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed, 77);
    Matrix::from_fn(rows, cols, |_, _| {
        let c = [-0.6, -0.2, 0.1, 0.45, 0.8][(rng.next_u32() % 5) as usize];
        ((c + rng.normal() * 0.04) * 256.0).round() / 256.0
    })
}

fn probe(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i as f64) * 0.531).cos() * 1.5).collect()
}

fn opts() -> QuantOptions {
    QuantOptions { kmeans_restarts: 1, ..QuantOptions::default() }
}

fn main() {
    let mut suite = Suite::with_config("Quantized matvec", active_config());
    let quick = std::env::var("SQLSQ_BENCH_QUICK").is_ok();

    // (rows, cols) per series point; rows is the reduction length. The
    // full run includes the ≥4096 acceptance point.
    let sizes: &[(usize, usize)] =
        if quick { &[(512, 64)] } else { &[(1024, 128), (4096, 256), (8192, 256)] };
    let bit_widths: &[u32] = if quick { &[2, 4] } else { &[2, 4, 8] };

    // --- throughput series: packed vs decode_dense vs dense_pre --------
    let mut series: Vec<Json> = Vec::new();
    for &(rows, cols) in sizes {
        let m = weights(rows, cols, rows as u64);
        let x = probe(rows);
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        for &bits in bit_widths {
            let qm = QMatrix::quantize(&m, Grouping::PerColumn, QuantMethod::KMeans, &opts(), bits)
                .expect("bench build");
            let tag = format!("{rows}x{cols}/b={bits}");

            // Copy each median out immediately: `case` hands back a
            // reference into the suite, which the next `case` call would
            // invalidate.
            let packed = suite
                .case(&format!("qmatvec/packed/f64/{tag}"), || {
                    black_box(qm.matvec(black_box(&x)));
                })
                .median;

            let q32 = qm.to_f32();
            let packed32 = suite
                .case(&format!("qmatvec/packed/f32/{tag}"), || {
                    black_box(q32.matvec(black_box(&x32)));
                })
                .median;

            let x_row = Matrix::from_vec(1, rows, x.clone()).unwrap();
            let decode_dense = suite
                .case(&format!("qmatvec/decode_dense/f64/{tag}"), || {
                    let dense = qm.decode();
                    black_box(x_row.matmul(black_box(&dense)).unwrap());
                })
                .median;

            let dense = qm.decode();
            let dense_pre = suite
                .case(&format!("qmatvec/dense_pre/f64/{tag}"), || {
                    black_box(x_row.matmul(black_box(&dense)).unwrap());
                })
                .median;

            let elems = (rows * cols) as f64;
            series.push(Json::obj(vec![
                ("rows", Json::Num(rows as f64)),
                ("cols", Json::Num(cols as f64)),
                ("bits", Json::Num(f64::from(bits))),
                ("packed_f64_median_s", Json::Num(packed)),
                ("packed_f32_median_s", Json::Num(packed32)),
                ("decode_dense_median_s", Json::Num(decode_dense)),
                ("dense_pre_median_s", Json::Num(dense_pre)),
                ("speedup_vs_decode", Json::Num(decode_dense / packed.max(1e-12))),
                ("speedup_vs_dense_pre", Json::Num(dense_pre / packed.max(1e-12))),
                ("packed_gelem_per_s", Json::Num(elems / packed.max(1e-12) / 1e9)),
            ]));
        }
    }

    // --- cascade series: error vs cumulative packed bits ----------------
    let (casc_rows, casc_cols) = if quick { (256, 32) } else { (1024, 128) };
    let m = weights(casc_rows, casc_cols, 9);
    let bit_list: &[u32] = &[4, 2, 2, 2];
    let (qm, trace) = QMatrix::residual_levels_traced(
        &m,
        Grouping::PerColumn,
        QuantMethod::KMeans,
        &opts(),
        bit_list,
        0.0,
    )
    .expect("cascade build");
    let x = probe(casc_rows);
    suite.case(&format!("qmatvec/cascade{}l/{casc_rows}x{casc_cols}", qm.num_levels()), || {
        black_box(qm.matvec(black_box(&x)));
    });
    let stats = qm.stats();
    let cascade: Vec<Json> = trace
        .iter()
        .enumerate()
        .map(|(l, lv)| {
            Json::obj(vec![
                ("level", Json::Num(l as f64)),
                ("bits", Json::Num(f64::from(lv.bits))),
                ("cum_bits", Json::Num(f64::from(lv.cum_bits))),
                ("rel_error", Json::Num(lv.rel_error)),
            ])
        })
        .collect();

    suite.write_csv(std::path::Path::new("reports")).ok();

    let cases: Vec<Json> = suite
        .rows()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("median_s", Json::Num(s.median)),
                ("min_s", Json::Num(s.min)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("qmatvec".into())),
        ("quick", Json::Bool(quick)),
        ("series", Json::Arr(series)),
        ("cascade", Json::Arr(cascade)),
        (
            "cascade_stats",
            Json::obj(vec![
                ("rows", Json::Num(casc_rows as f64)),
                ("cols", Json::Num(casc_cols as f64)),
                ("bits_per_idx_packed", Json::Num(f64::from(stats.bits_per_idx_packed))),
                ("compact_bytes", Json::Num(stats.compact_bytes as f64)),
                ("dense_bytes", Json::Num(stats.dense_bytes as f64)),
                ("byte_ratio", Json::Num(stats.byte_ratio)),
            ]),
        ),
        ("cases", Json::Arr(cases)),
    ]);
    if let Err(e) = std::fs::write("BENCH_qmatvec.json", json.to_pretty()) {
        eprintln!("warning: could not write BENCH_qmatvec.json: {e}");
    }
}
