//! Network-serve bench (ISSUE-9 acceptance): a real [`Server`] on a
//! loopback socket, measured four ways —
//!
//! * **round-trip** — single-client request latency per codec, on a
//!   cache-warm request (isolates framing + codec + socket overhead
//!   from solve time);
//! * **load** — the deterministic loadgen mix per codec: throughput,
//!   p50/p95/p99 latency, shed rate;
//! * **shed** — a tiny queue (1 worker, capacity 1) under an 8-way
//!   flood: how the admission path behaves at saturation;
//! * **fairness** — tenant token buckets on, two equal tenants: the
//!   per-tenant completion split.
//!
//! Emits `BENCH_serve_load.json` with the suite cases plus one `runs`
//! entry per load run. `SQLSQ_BENCH_QUICK=1` shrinks job counts for CI.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::{Coordinator, Payload};
use sqlsq::jsonio::Json;
use sqlsq::quant::{QuantMethod, QuantOptions};
use sqlsq::serve::{
    run_load, Client, Codec, LoadReport, LoadSpec, ServeConfig, Server, WireReply, WireRequest,
};

fn start_server(workers: usize, queue_capacity: usize, tenant_rate: f64) -> Server {
    let cfg = Config {
        workers,
        queue_capacity,
        engine: Engine::parse("native").expect("native engine"),
        ..Config::default()
    };
    let coord = Coordinator::start(cfg).expect("coordinator");
    Server::start(
        coord,
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            tenant_rate,
            tenant_burst: 2.0,
            ..ServeConfig::default()
        },
    )
    .expect("server")
}

fn small_request() -> WireRequest {
    let data: Vec<f64> =
        (0..64).map(|j| if j % 2 == 0 { 1.0 } else { -1.0 } + (j as f64) * 1e-3).collect();
    WireRequest {
        method: QuantMethod::KMeans,
        opts: QuantOptions { target_values: 4, kmeans_restarts: 1, ..Default::default() },
        payload: Payload::F64(data.into()),
        weights: None,
    }
}

/// `report.to_json()` plus a `run` tag so the series are self-labelling.
fn tagged(tag: &str, report: &LoadReport) -> Json {
    match report.to_json() {
        Json::Obj(mut m) => {
            m.insert("run".into(), Json::Str(tag.into()));
            Json::Obj(m)
        }
        other => other,
    }
}

fn main() {
    let mut suite = Suite::with_config("Serve load", active_config());
    let quick = std::env::var("SQLSQ_BENCH_QUICK").is_ok();
    let jobs = if quick { 24 } else { 192 };
    let n = if quick { 64 } else { 256 };
    let mut runs: Vec<Json> = Vec::new();

    // --- round-trip latency + steady-state load, per codec -------------
    {
        let server = start_server(2, Config::default().queue_capacity, 0.0);
        let addr = server.addr().to_string();
        for codec in [Codec::Json, Codec::Binary] {
            let mut client =
                Client::connect(&addr, codec, Some("bench")).expect("client connect");
            let req = small_request();
            // The identical request repeats, so after the first solve the
            // server answers from its result cache: the case isolates
            // frame + codec + socket overhead, which is what differs
            // between the two codecs.
            suite.case(&format!("serve/roundtrip_cached/{}", codec.id()), || {
                match client.quant(&req).expect("round trip") {
                    WireReply::Result(r) => {
                        black_box(r.l2_loss);
                    }
                    other => panic!("unexpected reply: {other:?}"),
                }
            });
            drop(client);

            let report = run_load(&LoadSpec {
                addr: addr.clone(),
                jobs,
                conns: 4,
                tenants: 2,
                codec,
                distinct: 8,
                n,
                seed: 1,
            })
            .expect("load run");
            println!("load/{}: {}", codec.id(), report.summary());
            runs.push(tagged(&format!("load_{}", codec.id()), &report));
        }
        let snap = server.shutdown();
        println!("steady-state server drained: {}", snap.summary());
    }

    // --- saturation: tiny queue, wide flood -----------------------------
    {
        let server = start_server(1, 1, 0.0);
        let report = run_load(&LoadSpec {
            addr: server.addr().to_string(),
            jobs,
            conns: 8,
            tenants: 2,
            codec: Codec::Binary,
            distinct: jobs, // all distinct: every job is a real solve
            n,
            seed: 7,
        })
        .expect("shed run");
        println!("shed: {}", report.summary());
        runs.push(tagged("shed_tiny_queue", &report));
        let snap = server.shutdown();
        println!("tiny-queue server drained: {}", snap.summary());
    }

    // --- fairness: tenant buckets on, two equal tenants -----------------
    {
        let server = start_server(2, Config::default().queue_capacity, 200.0);
        let report = run_load(&LoadSpec {
            addr: server.addr().to_string(),
            jobs,
            conns: 4,
            tenants: 2,
            codec: Codec::Binary,
            distinct: 8,
            n,
            seed: 3,
        })
        .expect("fairness run");
        println!("fairness: {}", report.summary());
        for (t, c) in &report.per_tenant_completed {
            println!("  {t}: {c}");
        }
        runs.push(tagged("fairness_two_tenants", &report));
        let snap = server.shutdown();
        println!("fairness server drained: {}", snap.summary());
    }

    suite.write_csv(std::path::Path::new("reports")).ok();

    let cases: Vec<Json> = suite
        .rows()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("median_s", Json::Num(s.median)),
                ("min_s", Json::Num(s.min)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("serve_load".into())),
        ("quick", Json::Bool(quick)),
        ("runs", Json::Arr(runs)),
        ("cases", Json::Arr(cases)),
    ]);
    match std::fs::write("BENCH_serve_load.json", json.to_pretty()) {
        Ok(()) => println!("[written BENCH_serve_load.json]"),
        Err(e) => eprintln!("warning: could not write BENCH_serve_load.json: {e}"),
    }
}
