//! Bench E9 (§3.6): the runtime crossover between k-means
//! (O(t·k·T·m)) and structured CD-LASSO (O(t·m)) as k approaches m.
//!
//! Reproduction target: with k ∈ Θ(m) ("high-resolution quantization"),
//! the proposed method wins by a growing factor as m scales.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::data::rng::Pcg32;
use sqlsq::eval::figures;
use sqlsq::quant::{self, QuantMethod, QuantOptions};

fn main() {
    let mut suite = Suite::with_config("Crossover kmeans vs l1 (k in Θ(m))", active_config());
    let mut rng = Pcg32::seeded(5);
    for &m in &[256usize, 512, 1024, 2048] {
        let data: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
        let k = m / 2;
        let opts_k = QuantOptions { target_values: k, seed: 1, ..Default::default() };
        suite.case(&format!("kmeans/m={m}/k={k}"), || {
            black_box(quant::quantize(&data, QuantMethod::KMeans, &opts_k).unwrap());
        });
        let lambda = figures::lambda_for_count(&data, k);
        let opts_l = QuantOptions { lambda1: lambda, ..Default::default() };
        suite.case(&format!("l1_ls/m={m}/k≈{k}"), || {
            black_box(quant::quantize(&data, QuantMethod::L1LeastSquare, &opts_l).unwrap());
        });
    }
    suite.write_csv(std::path::Path::new("reports")).ok();
}
