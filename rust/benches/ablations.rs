//! Ablation benches (DESIGN §5): the design choices the paper leaves
//! implicit, measured.
//!
//! * Lloyd+restarts vs exact DP k-means — is the heuristic the bottleneck?
//! * fuzzy c-means vs k-means — the Wen & Celebi "slower, not better"
//!   claim the paper cites to exclude FCM.
//! * CD-LASSO vs the exact fused-lasso DP at equal λ.
//! * k-means++ vs naive init (quality via restarts is Fig-1 territory;
//!   here we measure the cost).

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::cluster::fuzzy_cmeans::{fuzzy_cmeans_1d, FcmConfig};
use sqlsq::cluster::kmeans::{kmeans_1d, KMeansConfig, KMeansInit};
use sqlsq::cluster::kmeans_dp::kmeans_dp;
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{lasso, tv_exact, unique::UniqueDecomp, vmatrix::VBasis};

fn main() {
    let mut suite = Suite::with_config("Ablations", active_config());
    let mut rng = Pcg32::seeded(11);
    let data: Vec<f64> = (0..1000).map(|_| rng.uniform(0.0, 100.0)).collect();

    for &k in &[8usize, 64] {
        suite.case(&format!("kmeans_lloyd10/k={k}"), || {
            black_box(
                kmeans_1d(&data, None, &KMeansConfig { k, ..Default::default() }).unwrap(),
            );
        });
        suite.case(&format!("kmeans_exact_dp/k={k}"), || {
            black_box(kmeans_dp(&data, None, k).unwrap());
        });
        suite.case(&format!("fuzzy_cmeans/k={k}"), || {
            black_box(
                fuzzy_cmeans_1d(&data, None, &FcmConfig { k, ..Default::default() }).unwrap(),
            );
        });
        suite.case(&format!("kmeans_naive_init1/k={k}"), || {
            black_box(
                kmeans_1d(
                    &data,
                    None,
                    &KMeansConfig {
                        k,
                        restarts: 1,
                        init: KMeansInit::RandomValues,
                        repair_empty: false,
                        ..Default::default()
                    },
                )
                .unwrap(),
            );
        });
    }

    // CD vs exact DP on eq 6.
    let u = UniqueDecomp::new(&data).unwrap();
    let basis = VBasis::new(&u.values);
    for lambda in [0.5f64, 5.0] {
        let cfg = lasso::LassoConfig { lambda1: lambda, ..Default::default() };
        suite.case(&format!("lasso_cd/λ={lambda}"), || {
            black_box(lasso::solve(&basis, &u.values, &cfg, None).unwrap());
        });
        suite.case(&format!("tv_exact_dp/λ={lambda}"), || {
            black_box(tv_exact::solve_tv_exact(&basis, &u.values, lambda).unwrap());
        });
    }

    suite.write_csv(std::path::Path::new("reports")).ok();
}
