//! Bench E1 (Figure 1, runtime panel): quantization wall-time on the MLP
//! last-layer weights for every method, across value counts.
//!
//! Reproduction target (paper §4.1): the l1 family runs well below the
//! k-means family; cluster-LS adds negligible time over k-means.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::eval::{figures, workloads};
use sqlsq::quant::{self, QuantMethod, QuantOptions};

fn main() {
    let nn = workloads::nn_workload(None).expect("workload");
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut suite = Suite::with_config("Fig1 NN last-layer quantization time", active_config());

    for &k in &[8usize, 32, 128] {
        for method in [
            QuantMethod::KMeans,
            QuantMethod::ClusterLs,
            QuantMethod::Gmm,
            QuantMethod::DataTransform,
        ] {
            let opts = QuantOptions { target_values: k, seed: 1, ..Default::default() };
            suite.case(&format!("{}/k={k}", method.id()), || {
                black_box(quant::quantize(&weights, method, &opts).unwrap());
            });
        }
        let lambda = figures::lambda_for_count(&weights, k);
        for method in [QuantMethod::L1, QuantMethod::L1LeastSquare] {
            let opts = QuantOptions { lambda1: lambda, ..Default::default() };
            suite.case(&format!("{}/k≈{k}", method.id()), || {
                black_box(quant::quantize(&weights, method, &opts).unwrap());
            });
        }
    }
    suite.write_csv(std::path::Path::new("reports")).ok();
}
