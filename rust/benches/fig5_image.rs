//! Bench E5 (Figure 5): digit-image quantization wall-time per method/k.
//!
//! Reproduction target (paper §4.2): the l1-based approaches provide a
//! significant runtime advantage over the k-means family; cluster-LS costs
//! ≈ k-means.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::eval::{figures, workloads};
use sqlsq::quant::{self, QuantMethod, QuantOptions};

fn main() {
    let image = workloads::digit_image();
    let mut suite = Suite::with_config("Fig5 image quantization time", active_config());
    for &k in &[4usize, 16, 64] {
        for method in [QuantMethod::KMeans, QuantMethod::ClusterLs, QuantMethod::IterativeL1] {
            let opts = QuantOptions {
                target_values: k,
                lambda1: 1e-4,
                clamp: Some((0.0, 1.0)),
                seed: 1,
                ..Default::default()
            };
            suite.case(&format!("{}/k={k}", method.id()), || {
                black_box(quant::quantize(&image, method, &opts).unwrap());
            });
        }
        let lambda = figures::lambda_for_count(&image, k);
        let opts = QuantOptions {
            lambda1: lambda,
            clamp: Some((0.0, 1.0)),
            ..Default::default()
        };
        suite.case(&format!("l1_ls/k≈{k}"), || {
            black_box(quant::quantize(&image, QuantMethod::L1LeastSquare, &opts).unwrap());
        });
    }
    suite.write_csv(std::path::Path::new("reports")).ok();
}
