//! Bench E4 (Figure 4): solver cost of sole-l1 vs l1+negative-l2 across
//! λ₁ (λ₂ = 4e-3·λ₁, the paper's coupling), on the NN last layer.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::eval::workloads;
use sqlsq::quant::{self, QuantMethod, QuantOptions};

fn main() {
    let nn = workloads::nn_workload(None).expect("workload");
    let weights = nn.mlp.layer_weights(3).to_vec();
    let mut suite = Suite::with_config("Fig4 l1 vs l1+l2 solve time", active_config());
    for &lambda in &[1e-3f64, 1e-2, 1e-1] {
        let l1 = QuantOptions { lambda1: lambda, refit: false, ..Default::default() };
        suite.case(&format!("l1/λ={lambda:.0e}"), || {
            black_box(quant::quantize(&weights, QuantMethod::L1, &l1).unwrap());
        });
        let l1l2 = QuantOptions {
            lambda1: lambda,
            lambda2: 4e-3 * lambda,
            refit: false,
            ..Default::default()
        };
        suite.case(&format!("l1_l2/λ={lambda:.0e}"), || {
            black_box(quant::quantize(&weights, QuantMethod::L1L2, &l1l2).unwrap());
        });
    }
    suite.write_csv(std::path::Path::new("reports")).ok();
}
