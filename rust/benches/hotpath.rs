//! §Perf hot-path microbenchmarks (DESIGN §9): the before/after evidence
//! for every optimization EXPERIMENTS.md records.
//!
//! * per-kernel series: `linalg::kernels` vs deliberately naive scalar
//!   references, both precision lanes, across sizes — emitted into
//!   `BENCH_hotpath.json` (the ISSUE-6 acceptance series);
//! * structured O(m)/epoch CD vs the dense O(m²)/epoch oracle;
//! * O(m) segment-mean refit vs the eq-9 normal-equation solve;
//! * structured V ops vs dense matvec;
//! * 1-d bisection assignment vs linear-scan k-means;
//! * coordinator queue round-trip overhead.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::cluster::kmeans::assign_sorted;
use sqlsq::data::rng::Pcg32;
use sqlsq::jsonio::Json;
use sqlsq::linalg::kernels;
use sqlsq::linalg::scalar::Scalar;
use sqlsq::quant::{lasso, refit, unique::UniqueDecomp, vmatrix::VBasis};

fn sorted_values(m: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    v
}

// ---------------------------------------------------------------------
// Scalar references for the per-kernel series: deliberately naive
// indexed, bounds-checked loops, never inlined, so the comparison
// measures the kernel layer against the code shape the hot path used
// before it existed — not two spellings of the same optimized loop.
// ---------------------------------------------------------------------

#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn ref_sum<T: Scalar>(xs: &[T]) -> T {
    let mut acc = T::ZERO;
    for i in 0..xs.len() {
        acc += xs[i];
    }
    acc
}

#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn ref_dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    let mut acc = T::ZERO;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn ref_axpy<T: Scalar>(a: T, x: &[T], y: &mut [T]) {
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// The pre-kernel two-loop CD coordinate update of `solve_dense`: strict
/// suffix loop, open-coded soft threshold, then a separate correction
/// loop recomputing `d_j·δ` per row.
#[inline(never)]
#[allow(clippy::needless_range_loop)]
fn ref_shrink_axpy<T: Scalar>(
    r: &mut [T],
    dj: T,
    cj: T,
    alpha_j: T,
    lambda1: T,
    denom: T,
) -> (T, T) {
    let mut suffix = T::ZERO;
    for i in 0..r.len() {
        suffix += r[i];
    }
    let rho = suffix * dj + cj * alpha_j;
    let shrunk = if rho > lambda1 {
        rho - lambda1
    } else if rho < -lambda1 {
        rho + lambda1
    } else {
        T::ZERO
    };
    let new = shrunk / denom;
    let delta = new - alpha_j;
    if delta != T::ZERO {
        for i in 0..r.len() {
            r[i] -= dj * delta;
        }
    }
    (new, delta)
}

fn kernel_row(kernel: &str, lane: &str, n: usize, ref_s: f64, kern_s: f64) -> Json {
    Json::obj(vec![
        ("kernel", Json::Str(kernel.into())),
        ("lane", Json::Str(lane.into())),
        ("n", Json::Num(n as f64)),
        ("ref_median_s", Json::Num(ref_s)),
        ("kernel_median_s", Json::Num(kern_s)),
        ("speedup", Json::Num(ref_s / kern_s.max(1e-12))),
    ])
}

/// One lane × one size of the per-kernel series (ref vs kernel for each
/// primitive the CD hot path rides on).
fn kernel_series<T: Scalar>(suite: &mut Suite, n: usize, rows: &mut Vec<Json>) {
    let lane = T::ID;
    let a: Vec<T> = (0..n).map(|i| T::from_f64(((i as f64) * 0.7311).sin() * 1.5)).collect();
    let b: Vec<T> = (0..n).map(|i| T::from_f64(((i as f64) * 0.389).cos() * 0.8)).collect();

    let r = suite.case(&format!("kernel_ref/sum/{lane}/n={n}"), || {
        black_box(ref_sum(black_box(&a)));
    });
    let ref_s = r.median;
    let k = suite.case(&format!("kernel/sum/{lane}/n={n}"), || {
        black_box(kernels::sum(black_box(&a)));
    });
    rows.push(kernel_row("sum", lane, n, ref_s, k.median));

    let r = suite.case(&format!("kernel_ref/dot/{lane}/n={n}"), || {
        black_box(ref_dot(black_box(&a), black_box(&b)));
    });
    let ref_s = r.median;
    let k = suite.case(&format!("kernel/dot/{lane}/n={n}"), || {
        black_box(kernels::dot(black_box(&a), black_box(&b)));
    });
    rows.push(kernel_row("dot", lane, n, ref_s, k.median));

    let scale = T::from_f64(1.000001);
    let mut y = b.clone();
    let r = suite.case(&format!("kernel_ref/axpy/{lane}/n={n}"), || {
        ref_axpy(scale, black_box(&a), black_box(&mut y));
        black_box(y[0]);
    });
    let ref_s = r.median;
    let mut y = b.clone();
    let k = suite.case(&format!("kernel/axpy/{lane}/n={n}"), || {
        kernels::axpy(scale, black_box(&a), black_box(&mut y));
        black_box(y[0]);
    });
    rows.push(kernel_row("axpy", lane, n, ref_s, k.median));

    // shrink_axpy drives the residual toward its one-coordinate fixed
    // point (δ → 0 after one call), so each iteration perturbs one row
    // first — O(1), identical on both sides — to keep the correction
    // loop live.
    let dj = T::ONE;
    let cj = T::from_usize(n);
    let alpha_j = T::from_f64(0.3);
    let lambda1 = T::from_f64(0.01);
    let mut r_buf = a.clone();
    let mut i = 0usize;
    let r = suite.case(&format!("kernel_ref/shrink_axpy/{lane}/n={n}"), || {
        i = (i + 1) % n.max(1);
        r_buf[i] += T::ONE;
        black_box(ref_shrink_axpy(black_box(&mut r_buf), dj, cj, alpha_j, lambda1, cj));
    });
    let ref_s = r.median;
    let mut r_buf = a.clone();
    let mut i = 0usize;
    let k = suite.case(&format!("kernel/shrink_axpy/{lane}/n={n}"), || {
        i = (i + 1) % n.max(1);
        r_buf[i] += T::ONE;
        black_box(kernels::shrink_axpy(black_box(&mut r_buf), dj, cj, alpha_j, lambda1, cj));
    });
    rows.push(kernel_row("shrink_axpy", lane, n, ref_s, k.median));
}

fn main() {
    let mut suite = Suite::with_config("Hot paths", active_config());

    // --- per-kernel series (ISSUE-6 acceptance): ref vs kernel ---------
    let quick = std::env::var("SQLSQ_BENCH_QUICK").is_ok();
    let kernel_sizes: &[usize] = if quick { &[512, 1024] } else { &[1024, 4096, 16384] };
    let mut kernel_rows: Vec<Json> = Vec::new();
    for &n in kernel_sizes {
        kernel_series::<f64>(&mut suite, n, &mut kernel_rows);
        kernel_series::<f32>(&mut suite, n, &mut kernel_rows);
    }

    // Bit-plane kernels (pack/unpack) — kernel-only series: the "before"
    // was not storing a packed plane at all, so there is no scalar
    // reference to race; the number that matters is the absolute cost
    // composing with the packed-codebook win.
    {
        let n = *kernel_sizes.last().unwrap();
        let idx: Vec<u32> = (0..n).map(|i| ((i * 7) % 300) as u32).collect();
        let bits = kernels::bits_per_index_for(300);
        suite.case(&format!("kernel/pack_indices/9b/n={n}"), || {
            black_box(kernels::pack_indices(black_box(&idx), bits));
        });
        let words = kernels::pack_indices(&idx, bits);
        suite.case(&format!("kernel/unpack_indices/9b/n={n}"), || {
            black_box(kernels::unpack_indices(black_box(&words), bits, n));
        });
    }

    // --- CD epochs: structured vs dense --------------------------------
    for &m in &[256usize, 1024] {
        let v = sorted_values(m, 1);
        let basis = VBasis::new(&v);
        let cfg = lasso::LassoConfig {
            lambda1: 0.02,
            max_epochs: 10,
            tol: 0.0,
            ..Default::default()
        };
        suite.case(&format!("lasso_structured/m={m}/10ep"), || {
            black_box(lasso::solve(&basis, &v, &cfg, None).unwrap());
        });
        suite.case(&format!("lasso_dense/m={m}/10ep"), || {
            black_box(lasso::solve_dense(&basis, &v, &cfg, None).unwrap());
        });
    }

    // --- refit: segment means vs normal equations ----------------------
    let v = sorted_values(1024, 2);
    let basis = VBasis::new(&v);
    let support: Vec<usize> = (0..basis.m()).step_by(4).collect();
    suite.case("refit_fast/m=1024/h=256", || {
        black_box(refit::refit_fast(&basis, &v, &support, None).unwrap());
    });
    suite.case("refit_normal_eq/m=1024/h=256", || {
        black_box(refit::refit_normal_eq(&basis, &v, &support).unwrap());
    });

    // --- V ops: structured vs dense -------------------------------------
    let alpha: Vec<f64> = (0..basis.m()).map(|i| (i % 7) as f64 * 0.1).collect();
    let dense = basis.dense();
    suite.case("v_apply_structured/m=1024", || {
        black_box(basis.apply(&alpha));
    });
    suite.case("v_apply_dense/m=1024", || {
        black_box(dense.matvec(&alpha).unwrap());
    });

    // --- k-means assignment: bisection vs linear scan -------------------
    let cents = sorted_values(64, 3);
    let pts = sorted_values(4096, 4);
    suite.case("assign_bisect/m=4096/k=64", || {
        let mut acc = 0usize;
        for &p in &pts {
            acc += assign_sorted(p, &cents);
        }
        black_box(acc);
    });
    suite.case("assign_linear/m=4096/k=64", || {
        let mut acc = 0usize;
        for &p in &pts {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, &cv) in cents.iter().enumerate() {
                let d = (p - cv).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            acc += best;
        }
        black_box(acc);
    });

    // --- unique decomposition -------------------------------------------
    let mut rng = Pcg32::seeded(6);
    let raw: Vec<f64> = (0..8192).map(|_| (rng.uniform(0.0, 1.0) * 500.0).round() / 500.0).collect();
    suite.case("unique_decomp/n=8192", || {
        black_box(UniqueDecomp::new(&raw).unwrap());
    });

    // --- coordinator round trip ------------------------------------------
    let coord = sqlsq::coordinator::Coordinator::start(sqlsq::config::Config {
        workers: 2,
        engine: sqlsq::config::Engine::Native,
        ..Default::default()
    })
    .unwrap();
    let small: Vec<f64> = sorted_values(64, 7);
    suite.case("coordinator_roundtrip/kmeans/m=64", || {
        let r = coord
            .quantize_blocking(
                small.clone(),
                sqlsq::quant::QuantMethod::KMeans,
                sqlsq::quant::QuantOptions { target_values: 4, ..Default::default() },
            )
            .unwrap();
        black_box(r.is_ok());
    });
    coord.shutdown();

    suite.write_csv(std::path::Path::new("reports")).ok();

    // Machine-readable evidence: the per-kernel series plus every suite
    // case, so downstream tooling (and the acceptance check) can read
    // speedups without scraping stdout.
    let sizes_json: Vec<Json> = kernel_sizes.iter().map(|&n| Json::Num(n as f64)).collect();
    let cases: Vec<Json> = suite
        .rows()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("median_s", Json::Num(s.median)),
                ("min_s", Json::Num(s.min)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("quick", Json::Bool(quick)),
        ("kernel_sizes", Json::Arr(sizes_json)),
        ("kernels", Json::Arr(kernel_rows)),
        ("cases", Json::Arr(cases)),
    ]);
    if let Err(e) = std::fs::write("BENCH_hotpath.json", json.to_pretty()) {
        eprintln!("warning: could not write BENCH_hotpath.json: {e}");
    }
}
