//! §Perf hot-path microbenchmarks (DESIGN §9): the before/after evidence
//! for every optimization EXPERIMENTS.md records.
//!
//! * structured O(m)/epoch CD vs the dense O(m²)/epoch oracle;
//! * O(m) segment-mean refit vs the eq-9 normal-equation solve;
//! * structured V ops vs dense matvec;
//! * 1-d bisection assignment vs linear-scan k-means;
//! * coordinator queue round-trip overhead.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::cluster::kmeans::assign_sorted;
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{lasso, refit, unique::UniqueDecomp, vmatrix::VBasis};

fn sorted_values(m: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    let mut v: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    v
}

fn main() {
    let mut suite = Suite::with_config("Hot paths", active_config());

    // --- CD epochs: structured vs dense --------------------------------
    for &m in &[256usize, 1024] {
        let v = sorted_values(m, 1);
        let basis = VBasis::new(&v);
        let cfg = lasso::LassoConfig {
            lambda1: 0.02,
            max_epochs: 10,
            tol: 0.0,
            ..Default::default()
        };
        suite.case(&format!("lasso_structured/m={m}/10ep"), || {
            black_box(lasso::solve(&basis, &v, &cfg, None).unwrap());
        });
        suite.case(&format!("lasso_dense/m={m}/10ep"), || {
            black_box(lasso::solve_dense(&basis, &v, &cfg, None).unwrap());
        });
    }

    // --- refit: segment means vs normal equations ----------------------
    let v = sorted_values(1024, 2);
    let basis = VBasis::new(&v);
    let support: Vec<usize> = (0..basis.m()).step_by(4).collect();
    suite.case("refit_fast/m=1024/h=256", || {
        black_box(refit::refit_fast(&basis, &v, &support, None).unwrap());
    });
    suite.case("refit_normal_eq/m=1024/h=256", || {
        black_box(refit::refit_normal_eq(&basis, &v, &support).unwrap());
    });

    // --- V ops: structured vs dense -------------------------------------
    let alpha: Vec<f64> = (0..basis.m()).map(|i| (i % 7) as f64 * 0.1).collect();
    let dense = basis.dense();
    suite.case("v_apply_structured/m=1024", || {
        black_box(basis.apply(&alpha));
    });
    suite.case("v_apply_dense/m=1024", || {
        black_box(dense.matvec(&alpha).unwrap());
    });

    // --- k-means assignment: bisection vs linear scan -------------------
    let cents = sorted_values(64, 3);
    let pts = sorted_values(4096, 4);
    suite.case("assign_bisect/m=4096/k=64", || {
        let mut acc = 0usize;
        for &p in &pts {
            acc += assign_sorted(p, &cents);
        }
        black_box(acc);
    });
    suite.case("assign_linear/m=4096/k=64", || {
        let mut acc = 0usize;
        for &p in &pts {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, &cv) in cents.iter().enumerate() {
                let d = (p - cv).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            acc += best;
        }
        black_box(acc);
    });

    // --- unique decomposition -------------------------------------------
    let mut rng = Pcg32::seeded(6);
    let raw: Vec<f64> = (0..8192).map(|_| (rng.uniform(0.0, 1.0) * 500.0).round() / 500.0).collect();
    suite.case("unique_decomp/n=8192", || {
        black_box(UniqueDecomp::new(&raw).unwrap());
    });

    // --- coordinator round trip ------------------------------------------
    let coord = sqlsq::coordinator::Coordinator::start(sqlsq::config::Config {
        workers: 2,
        engine: sqlsq::config::Engine::Native,
        ..Default::default()
    })
    .unwrap();
    let small: Vec<f64> = sorted_values(64, 7);
    suite.case("coordinator_roundtrip/kmeans/m=64", || {
        let r = coord
            .quantize_blocking(
                small.clone(),
                sqlsq::quant::QuantMethod::KMeans,
                sqlsq::quant::QuantOptions { target_values: 4, ..Default::default() },
            )
            .unwrap();
        black_box(r.is_ok());
    });
    coord.shutdown();

    suite.write_csv(std::path::Path::new("reports")).ok();
}
