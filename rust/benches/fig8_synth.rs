//! Bench E8 (Figure 8, runtime panels): quantization wall-time on the
//! three §4.3 synthetic datasets.

use sqlsq::bench_support::{active_config, black_box, Suite};
use sqlsq::eval::workloads;
use sqlsq::quant::{self, QuantMethod, QuantOptions};

fn main() {
    let mut suite = Suite::with_config("Fig8 synthetic-data quantization time", active_config());
    for (kind, data) in workloads::synth_datasets(1) {
        for &k in &[8usize, 32] {
            for method in [
                QuantMethod::KMeans,
                QuantMethod::ClusterLs,
                QuantMethod::Gmm,
                QuantMethod::DataTransform,
                QuantMethod::IterativeL1,
                QuantMethod::L1LeastSquare,
            ] {
                let opts = QuantOptions {
                    target_values: k,
                    lambda1: 0.05,
                    clamp: Some((0.0, 100.0)),
                    seed: 2,
                    ..Default::default()
                };
                suite.case(&format!("{}/{}/k={k}", kind.label(), method.id()), || {
                    black_box(quant::quantize(&data, method, &opts).unwrap());
                });
            }
        }
    }
    suite.write_csv(std::path::Path::new("reports")).ok();
}
