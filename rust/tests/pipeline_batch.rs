//! Staged-pipeline equivalence properties (ISSUE 1): `quantize_batch` and
//! cold `quantize_sweep` must be bitwise-identical to per-call `quantize`
//! for every method, and the warm-started lasso λ path must be equivalent
//! (same near-optimal loss) to the cold one.

use sqlsq::quant::{self, PreparedInput, QuantMethod, QuantOptions};
use sqlsq::testkit::{check, gens};

const CASES: usize = 12;

fn base_opts() -> QuantOptions {
    QuantOptions {
        lambda1: 0.02,
        lambda2: 4e-5,
        target_values: 4,
        ..Default::default()
    }
}

/// Bitwise equality of two outputs (values, levels and loss).
fn assert_bitwise_eq(
    a: &quant::QuantOutput,
    b: &quant::QuantOutput,
    method: QuantMethod,
    what: &str,
) {
    assert_eq!(a.values, b.values, "{method:?}: {what} values differ");
    assert_eq!(a.levels, b.levels, "{method:?}: {what} levels differ");
    assert_eq!(
        a.l2_loss.to_bits(),
        b.l2_loss.to_bits(),
        "{method:?}: {what} loss differs"
    );
    assert_eq!(a.clamped, b.clamped, "{method:?}: {what} clamp count differs");
}

#[test]
fn prop_batch_bitwise_matches_per_call_for_all_methods() {
    check(
        "quantize_batch ≡ per-call quantize",
        CASES,
        gens::vec_clustered(8..=60, 4),
        |xs| {
            // Three shifted copies exercise distinct prepare stages.
            let inputs: Vec<Vec<f64>> = (0..3)
                .map(|k| xs.iter().map(|&x| x + 0.05 * k as f64).collect())
                .collect();
            for method in QuantMethod::ALL {
                let opts = base_opts();
                let batch = quant::quantize_batch(&inputs, method, &opts);
                for (w, got) in inputs.iter().zip(&batch) {
                    let got = got.as_ref().map_err(|e| e.to_string())?;
                    let single = quant::quantize(w, method, &opts).map_err(|e| e.to_string())?;
                    assert_bitwise_eq(got, &single, method, "batch");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cold_sweep_bitwise_matches_per_call_for_all_methods() {
    let lambdas = [1e-3, 1e-2, 1e-1];
    check(
        "cold quantize_sweep ≡ per-call quantize",
        CASES,
        gens::vec_clustered(8..=50, 4),
        |xs| {
            let prep = PreparedInput::new(xs).map_err(|e| e.to_string())?;
            for method in QuantMethod::ALL {
                let opts = base_opts();
                let swept = quant::quantize_sweep_with(&prep, method, &lambdas, &opts, false)
                    .map_err(|e| e.to_string())?;
                for (out, &lambda) in swept.iter().zip(&lambdas) {
                    let single = quant::quantize(
                        xs,
                        method,
                        &QuantOptions { lambda1: lambda, ..opts.clone() },
                    )
                    .map_err(|e| e.to_string())?;
                    assert_bitwise_eq(out, &single, method, "sweep");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_warm_sweep_equivalent_to_cold_on_lasso_path() {
    // The lasso objective is strongly convex (paper §3.2.1), so warm and
    // cold CD converge to the same optimum; the loss along the λ path must
    // agree closely even though the iterate paths (and hence exact bits)
    // differ — support-patience early stopping leaves a small slack.
    let lambdas = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1];
    check(
        "warm sweep ≈ cold sweep (lasso family)",
        CASES,
        gens::vec_clustered(8..=60, 4),
        |xs| {
            let prep = PreparedInput::new(xs).map_err(|e| e.to_string())?;
            for method in [QuantMethod::L1, QuantMethod::L1LeastSquare] {
                let opts = QuantOptions { lambda1: 0.0, ..Default::default() };
                let warm = quant::quantize_sweep(&prep, method, &lambdas, &opts)
                    .map_err(|e| e.to_string())?;
                let cold = quant::quantize_sweep_with(&prep, method, &lambdas, &opts, false)
                    .map_err(|e| e.to_string())?;
                for ((w, c), &lambda) in warm.iter().zip(&cold).zip(&lambdas) {
                    let tol = 1e-3 * (1.0 + c.l2_loss);
                    if (w.l2_loss - c.l2_loss).abs() > tol {
                        return Err(format!(
                            "{method:?} λ={lambda}: warm loss {} vs cold {}",
                            w.l2_loss, c.l2_loss
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Bitwise equality of two f32-lane outputs (values, levels and loss).
fn assert_bitwise_eq_f32(
    a: &quant::QuantOutputF32,
    b: &quant::QuantOutputF32,
    method: QuantMethod,
    what: &str,
) {
    assert_eq!(a.values, b.values, "{method:?}: {what} values differ");
    assert_eq!(a.levels, b.levels, "{method:?}: {what} levels differ");
    assert_eq!(
        a.l2_loss.to_bits(),
        b.l2_loss.to_bits(),
        "{method:?}: {what} loss differs"
    );
    assert_eq!(a.clamped, b.clamped, "{method:?}: {what} clamp count differs");
}

#[test]
fn prop_f32_batch_bitwise_matches_per_call_for_all_methods() {
    check(
        "f32 quantize_batch ≡ per-call quantize_f32",
        CASES,
        gens::vec_clustered(8..=60, 4),
        |xs| {
            let inputs: Vec<Vec<f32>> = (0..3)
                .map(|k| xs.iter().map(|&x| (x + 0.05 * k as f64) as f32).collect())
                .collect();
            for method in QuantMethod::ALL {
                let opts = base_opts();
                let batch = quant::quantize_batch_f32(&inputs, method, &opts);
                for (w, got) in inputs.iter().zip(&batch) {
                    let got = got.as_ref().map_err(|e| e.to_string())?;
                    let single =
                        quant::quantize_f32(w, method, &opts).map_err(|e| e.to_string())?;
                    assert_bitwise_eq_f32(got, &single, method, "f32 batch");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f32_cold_sweep_bitwise_matches_per_call_for_all_methods() {
    let lambdas = [1e-3, 1e-2, 1e-1];
    check(
        "f32 cold quantize_sweep ≡ per-call quantize_f32",
        CASES,
        gens::vec_clustered(8..=50, 4),
        |xs| {
            let xs32: Vec<f32> = xs.iter().map(|&x| x as f32).collect();
            let prep = quant::PreparedInputF32::new(&xs32).map_err(|e| e.to_string())?;
            for method in QuantMethod::ALL {
                let opts = base_opts();
                let swept =
                    quant::quantize_sweep_f32_with(&prep, method, &lambdas, &opts, false)
                        .map_err(|e| e.to_string())?;
                for (out, &lambda) in swept.iter().zip(&lambdas) {
                    let single = quant::quantize_f32(
                        &xs32,
                        method,
                        &QuantOptions { lambda1: lambda, ..opts.clone() },
                    )
                    .map_err(|e| e.to_string())?;
                    assert_bitwise_eq_f32(out, &single, method, "f32 sweep");
                }
            }
            Ok(())
        },
    );
}

#[test]
fn precision_option_batch_matches_per_call() {
    // opts.precision = F32 must route `quantize_batch` slots exactly like
    // one-shot `quantize` (both narrow per input, solve on the f32 lane,
    // and widen).
    let inputs: Vec<Vec<f64>> = (0..4)
        .map(|k| (0..80).map(|i| ((i * 7 + k * 3) % 13) as f64 * 0.07).collect())
        .collect();
    let opts = QuantOptions {
        lambda1: 0.03,
        precision: sqlsq::quant::Precision::F32,
        ..Default::default()
    };
    let batch = quant::quantize_batch(&inputs, QuantMethod::L1LeastSquare, &opts);
    for (w, got) in inputs.iter().zip(&batch) {
        let got = got.as_ref().unwrap();
        let single = quant::quantize(w, QuantMethod::L1LeastSquare, &opts).unwrap();
        assert_bitwise_eq(got, &single, QuantMethod::L1LeastSquare, "precision batch");
    }
}

#[test]
fn warm_sweep_reuses_fewer_epochs_than_cold_in_aggregate() {
    // The point of warm starts: across a dense λ path the warm sweep must
    // not consume more CD epochs than the cold one (ties allowed).
    let data: Vec<f64> = (0..600)
        .map(|i| ((i % 37) as f64 * 0.027 + (i % 11) as f64 * 0.003))
        .collect();
    let prep = PreparedInput::new(&data).unwrap();
    let lambdas: Vec<f64> =
        sqlsq::eval::workloads::lambda_grid(1e-4, 1e-1, 12).unwrap();
    let opts = QuantOptions::default();
    let warm = quant::quantize_sweep(&prep, QuantMethod::L1, &lambdas, &opts).unwrap();
    let cold =
        quant::quantize_sweep_with(&prep, QuantMethod::L1, &lambdas, &opts, false).unwrap();
    let warm_epochs: usize = warm.iter().map(|o| o.diag.iterations).sum();
    let cold_epochs: usize = cold.iter().map(|o| o.diag.iterations).sum();
    // One epoch of slack per grid point tolerates patience-stop jitter.
    assert!(
        warm_epochs <= cold_epochs + lambdas.len(),
        "warm path used more epochs ({warm_epochs}) than cold ({cold_epochs})"
    );
}
