//! Bitwise-equivalence suite for the request/response front door: every
//! legacy entry point is a shim over the `quant::api` core, and this file
//! proves each one produces outputs identical to a direct
//! `Quantizer::run` — values (`==`, which also pins the `-0.0`/`0.0`
//! fold), levels, loss *bits*, clamp counts and diagnostics — plus the
//! codebook round-trip property on both precision lanes. The ISSUE-8
//! result-cache invisibility pin lives at the bottom: a memoizing
//! [`Quantizer::caching`] facade must match the stateless facade bit for
//! bit across every (method, plan, lane). The ISSUE-10 pin sits beside
//! it: a uniform importance vector through the weighted front door is
//! bitwise-identical to the unweighted solve for every (method, plan,
//! lane).

use sqlsq::data::rng::Pcg32;
use sqlsq::linalg::matrix::Matrix;
use sqlsq::quant::tensor::{quantize_matrix, Grouping};
use sqlsq::quant::{
    self, Codebook, Item, OutputForm, Precision, QuantMethod, QuantOptions, QuantOutput,
    QuantRequest, Quantizer,
};

fn clustered(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let center = [0.1, 0.35, 0.6, 0.9][i % 4];
        // Round so repeats occur (multiplicities > 1).
        v.push(((center + rng.normal_with(0.0, 0.02)) * 200.0).round() / 200.0);
    }
    v
}

fn narrowed(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

fn test_opts() -> QuantOptions {
    QuantOptions { lambda1: 0.01, lambda2: 4e-5, target_values: 4, ..Default::default() }
}

fn assert_outputs_match(got: &QuantOutput, want: &QuantOutput, ctx: &str) {
    assert_eq!(got.values, want.values, "{ctx}: values");
    assert_eq!(got.levels, want.levels, "{ctx}: levels");
    assert_eq!(got.l2_loss.to_bits(), want.l2_loss.to_bits(), "{ctx}: loss bits");
    assert_eq!(got.clamped, want.clamped, "{ctx}: clamp count");
    assert_eq!(got.diag.nnz, want.diag.nnz, "{ctx}: nnz");
    assert_eq!(got.diag.iterations, want.diag.iterations, "{ctx}: iterations");
}

#[test]
fn legacy_quantize_matches_run_for_every_method() {
    let data = clustered(80, 1);
    for method in QuantMethod::ALL {
        let opts = test_opts();
        let legacy = quant::quantize(&data, method, &opts).unwrap();
        let req = QuantRequest::slice(&data).method(method).options(opts);
        let via_run =
            Quantizer::new().run(&req).unwrap().into_single().unwrap().into_output64();
        assert_outputs_match(&via_run, &legacy, &format!("{method:?}"));
    }
}

#[test]
fn legacy_quantize_with_clamp_matches_run() {
    let data = clustered(60, 2);
    let opts = QuantOptions { clamp: Some((0.05, 0.85)), ..test_opts() };
    let legacy = quant::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
    let req = QuantRequest::slice(&data).method(QuantMethod::KMeans).options(opts);
    let via_run = Quantizer::new().run(&req).unwrap().into_single().unwrap().into_output64();
    assert_outputs_match(&via_run, &legacy, "clamped kmeans");
    assert!(legacy.clamped > 0, "clamp should engage on this data");
}

#[test]
fn legacy_f32_precision_option_matches_run() {
    let data = clustered(70, 3);
    for method in [QuantMethod::L1, QuantMethod::L1LeastSquare, QuantMethod::KMeans] {
        let opts = QuantOptions { precision: Precision::F32, ..test_opts() };
        let legacy = quant::quantize(&data, method, &opts).unwrap();
        let req = QuantRequest::slice(&data).method(method).options(opts);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        assert_eq!(item.precision(), Precision::F32, "{method:?}: stays narrow");
        assert_outputs_match(&item.into_output64(), &legacy, &format!("{method:?} f32"));
    }
}

#[test]
fn legacy_quantize_f32_matches_run() {
    let data32 = narrowed(&clustered(60, 4));
    for method in [QuantMethod::L1LeastSquare, QuantMethod::ClusterLs] {
        let opts = test_opts();
        let legacy = quant::quantize_f32(&data32, method, &opts).unwrap();
        let req = QuantRequest::slice_f32(&data32).method(method).options(opts);
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let got = item.as_f32().expect("f32 lane").clone();
        assert_eq!(got.codebook.decode(), legacy.values, "{method:?}: values");
        assert_eq!(got.codebook.levels, legacy.levels, "{method:?}: levels");
        assert_eq!(got.l2_loss.to_bits(), legacy.l2_loss.to_bits(), "{method:?}: loss");
    }
}

#[test]
fn legacy_batch_matches_run_including_bad_slots() {
    let inputs = vec![clustered(50, 5), vec![], clustered(50, 6), clustered(30, 7)];
    let opts = test_opts();
    let legacy = quant::quantize_batch(&inputs, QuantMethod::KMeans, &opts);
    let req = QuantRequest::batch(inputs.clone()).method(QuantMethod::KMeans).options(opts);
    let via_run = Quantizer::new().run(&req).unwrap().into_outputs64();
    assert_eq!(legacy.len(), via_run.len());
    for (i, (l, r)) in legacy.iter().zip(&via_run).enumerate() {
        match (l, r) {
            (Ok(a), Ok(b)) => assert_outputs_match(b, a, &format!("slot {i}")),
            (Err(_), Err(_)) => {}
            other => panic!("slot {i}: ok/err mismatch: {other:?}"),
        }
    }
}

#[test]
fn legacy_batch_f32_matches_run() {
    let inputs32: Vec<Vec<f32>> =
        vec![narrowed(&clustered(40, 8)), narrowed(&clustered(40, 9))];
    let opts = QuantOptions { lambda1: 0.02, ..Default::default() };
    let legacy = quant::quantize_batch_f32(&inputs32, QuantMethod::L1LeastSquare, &opts);
    let req = QuantRequest::batch_f32(inputs32.clone())
        .method(QuantMethod::L1LeastSquare)
        .options(opts);
    let resp = Quantizer::new().run(&req).unwrap();
    assert_eq!(resp.len(), legacy.len());
    for (i, (l, r)) in legacy.iter().zip(&resp.items).enumerate() {
        let l = l.as_ref().unwrap();
        let item = r.as_ref().unwrap().as_f32().expect("f32 lane");
        assert_eq!(item.codebook.decode(), l.values, "slot {i}");
        assert_eq!(item.l2_loss.to_bits(), l.l2_loss.to_bits(), "slot {i}");
    }
}

#[test]
fn legacy_sweep_matches_run_warm_and_cold() {
    let data = clustered(64, 10);
    let lambdas = vec![1e-4, 1e-3, 1e-2, 1e-1];
    for method in [QuantMethod::L1, QuantMethod::L1LeastSquare, QuantMethod::IterativeL1] {
        for warm in [true, false] {
            let base = QuantOptions { target_values: 4, ..Default::default() };
            let prep = quant::PreparedInput::new(&data).unwrap();
            let legacy =
                quant::quantize_sweep_with(&prep, method, &lambdas, &base, warm).unwrap();
            let req = QuantRequest::slice(&data).method(method).options(base);
            let req =
                if warm { req.sweep(lambdas.clone()) } else { req.sweep_cold(lambdas.clone()) };
            let outs: Vec<QuantOutput> = Quantizer::new()
                .run(&req)
                .unwrap()
                .into_outputs64()
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(outs.len(), legacy.len());
            for (i, (got, want)) in outs.iter().zip(&legacy).enumerate() {
                assert_outputs_match(got, want, &format!("{method:?} warm={warm} λ#{i}"));
            }
        }
    }
}

#[test]
fn legacy_f32_sweep_matches_run() {
    let data32 = narrowed(&clustered(60, 11));
    let lambdas = vec![1e-3, 1e-2];
    let base = QuantOptions { target_values: 4, ..Default::default() };
    let prep = quant::PreparedInputF32::new(&data32).unwrap();
    let legacy = quant::quantize_sweep_f32(&prep, QuantMethod::L1LeastSquare, &lambdas, &base)
        .unwrap();
    let req = QuantRequest::slice_f32(&data32)
        .method(QuantMethod::L1LeastSquare)
        .options(base)
        .sweep(lambdas.clone());
    let resp = Quantizer::new().run(&req).unwrap();
    assert_eq!(resp.len(), legacy.len());
    for (r, want) in resp.items.iter().zip(&legacy) {
        let item = r.as_ref().unwrap().as_f32().expect("f32 lane");
        assert_eq!(item.codebook.decode(), want.values);
        assert_eq!(item.l2_loss.to_bits(), want.l2_loss.to_bits());
    }
}

#[test]
fn legacy_quantize_matrix_matches_run_and_serial_loop() {
    let mut rng = Pcg32::seeded(12);
    let m = Matrix::from_fn(6, 24, |_, _| (rng.normal_with(0.0, 1.0) * 50.0).round() / 50.0);
    for grouping in [Grouping::PerTensor, Grouping::PerRow, Grouping::PerColumn] {
        let opts = QuantOptions { target_values: 3, ..Default::default() };
        let legacy = quantize_matrix(&m, QuantMethod::KMeans, &opts, grouping).unwrap();

        // vs the request front door.
        let req = QuantRequest::matrix(m.clone(), grouping)
            .method(QuantMethod::KMeans)
            .options(opts.clone());
        let items = Quantizer::new().run(&req).unwrap().into_outputs64();
        assert_eq!(items.len(), legacy.outputs.len(), "{grouping:?}");
        for (got, want) in items.iter().zip(&legacy.outputs) {
            assert_outputs_match(got.as_ref().unwrap(), want, &format!("{grouping:?}"));
        }

        // vs the pre-redesign serial loop semantics: one quantize() per
        // group, in group order (pins that the batch fan-out changed
        // nothing).
        let groups: Vec<Vec<f64>> = match grouping {
            Grouping::PerTensor => vec![m.data().to_vec()],
            Grouping::PerRow => (0..m.rows()).map(|i| m.row(i).to_vec()).collect(),
            Grouping::PerColumn => (0..m.cols()).map(|j| m.col(j)).collect(),
        };
        assert_eq!(groups.len(), legacy.outputs.len());
        for (g, want) in groups.iter().zip(&legacy.outputs) {
            let serial = quant::quantize(g, QuantMethod::KMeans, &opts).unwrap();
            assert_eq!(serial.values, want.values, "{grouping:?}: serial reference");
            assert_eq!(serial.l2_loss.to_bits(), want.l2_loss.to_bits(), "{grouping:?}");
        }
    }
}

#[test]
fn legacy_quantize_timed_matches_untimed() {
    let data = clustered(60, 13);
    let opts = test_opts();
    let (out, t) = quant::quantize_timed(&data, QuantMethod::ClusterLs, &opts).unwrap();
    let want = quant::quantize(&data, QuantMethod::ClusterLs, &opts).unwrap();
    assert_outputs_match(&out, &want, "timed");
    assert!(t.prepare + t.solve < std::time::Duration::from_secs(60));
}

#[test]
fn codebook_roundtrip_property_both_lanes() {
    // encode → materialize == values, across seeds and methods, f64 + f32.
    for seed in 0..6u64 {
        let data = clustered(50 + 7 * seed as usize, 100 + seed);
        let method = [
            QuantMethod::KMeans,
            QuantMethod::L1LeastSquare,
            QuantMethod::ClusterLs,
        ][seed as usize % 3];
        let opts = test_opts();

        // f64 lane.
        let want = quant::quantize(&data, method, &opts).unwrap();
        let req = QuantRequest::slice(&data).method(method).options(opts.clone());
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let q = item.as_f64().expect("f64 lane");
        assert!(q.values().is_none(), "codebook form stays compact");
        assert_eq!(q.materialize(), want.values, "seed {seed}: decode == values");
        assert_eq!(q.codebook.levels, want.levels, "seed {seed}");
        // Re-encoding the materialized vector reproduces the codebook.
        let re = Codebook::from_values(&q.materialize()).unwrap();
        assert_eq!(re.levels, q.codebook.levels, "seed {seed}: re-encode levels");
        assert_eq!(re.indices, q.codebook.indices, "seed {seed}: re-encode indices");

        // f32 lane.
        let data32 = narrowed(&data);
        let want32 = quant::quantize_f32(&data32, method, &opts).unwrap();
        let req32 = QuantRequest::slice_f32(&data32).method(method).options(opts);
        let item32 = Quantizer::new().run(&req32).unwrap().into_single().unwrap();
        let q32 = item32.as_f32().expect("f32 lane");
        assert_eq!(q32.materialize(), want32.values, "seed {seed}: f32 decode");
        let re32 = Codebook::from_values(&q32.materialize()).unwrap();
        assert_eq!(re32.indices, q32.codebook.indices, "seed {seed}: f32 re-encode");
    }
}

#[test]
fn values_output_form_is_eager_and_identical() {
    let data = clustered(40, 20);
    let req = QuantRequest::vector(data.clone())
        .method(QuantMethod::KMeans)
        .target_count(4)
        .output(OutputForm::Values);
    let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
    match &item {
        Item::F64(q) => {
            let eager = q.values().expect("values form is eager").to_vec();
            assert_eq!(eager, q.codebook.decode());
        }
        Item::F32(_) => panic!("f64 input on the default lane"),
    }
}

#[test]
fn coordinator_legacy_submits_match_request_front_door() {
    use sqlsq::config::{Config, Engine};
    use sqlsq::coordinator::Coordinator;

    let cfg = Config {
        workers: 2,
        queue_capacity: 64,
        max_batch: 8,
        batch_wait_us: 100,
        engine: Engine::Native,
        ..Default::default()
    };
    let c = Coordinator::start(cfg).unwrap();
    let data = clustered(50, 30);
    let opts = QuantOptions { target_values: 4, seed: 3, ..Default::default() };

    let direct = quant::quantize(&data, QuantMethod::KMeans, &opts).unwrap();
    let legacy = c
        .quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone())
        .unwrap()
        .outcome
        .unwrap()
        .into_output64();
    let via_request = c
        .quantize_blocking_request(
            QuantRequest::vector(data.clone()).method(QuantMethod::KMeans).options(opts.clone()),
        )
        .unwrap()
        .outcome
        .unwrap()
        .into_output64();
    assert_outputs_match(&legacy, &direct, "legacy submit");
    assert_outputs_match(&via_request, &direct, "request submit");

    // f32 payloads: legacy f32 submit == request with an f32 vector.
    let data32 = narrowed(&data);
    let opts32 = QuantOptions { lambda1: 0.05, ..Default::default() };
    let legacy32 = c
        .quantize_blocking_f32(data32.clone(), QuantMethod::L1LeastSquare, opts32.clone())
        .unwrap()
        .outcome
        .unwrap()
        .into_output64();
    let via_request32 = c
        .quantize_blocking_request(
            QuantRequest::vector_f32(data32.clone())
                .method(QuantMethod::L1LeastSquare)
                .options(opts32),
        )
        .unwrap()
        .outcome
        .unwrap()
        .into_output64();
    assert_outputs_match(&via_request32, &legacy32, "f32 request submit");
    c.shutdown();
}

#[test]
fn uniform_weights_are_bitwise_identical_to_unweighted_for_every_method_plan_lane() {
    // ISSUE-10 acceptance pin: a uniform importance vector (any constant,
    // not just 1.0) is normalized away before dispatch, so the weighted
    // front door must reproduce the unweighted solve bit for bit — for
    // every method (including L0/TvExact, which reject *non-uniform*
    // weights), both precision lanes, and the single-vector plans.
    let data = clustered(64, 21);
    let plans: [(&str, fn(QuantRequest) -> QuantRequest); 3] = [
        ("one-shot", |r| r),
        ("target-count", |r| r.target_count(5)),
        ("warm-sweep", |r| r.sweep(vec![0.02, 0.01, 0.005])),
    ];
    let bits = |v: Vec<f64>| -> Vec<u64> { v.into_iter().map(f64::to_bits).collect() };
    for method in QuantMethod::ALL {
        for lane in [Precision::F64, Precision::F32] {
            for (plan_name, plan) in plans {
                let ctx = format!("{method:?}/{lane:?}/{plan_name}");
                let build = || {
                    plan(
                        QuantRequest::slice(&data)
                            .method(method)
                            .options(QuantOptions { precision: lane, ..test_opts() }),
                    )
                };
                let plain = Quantizer::new().run(&build()).unwrap();
                let uniform =
                    Quantizer::new().run(&build().weights(vec![2.5; data.len()])).unwrap();
                assert_eq!(uniform.items.len(), plain.items.len(), "{ctx}: item count");
                for (i, (g, c)) in uniform.items.iter().zip(&plain.items).enumerate() {
                    let g = g.as_ref().unwrap_or_else(|e| panic!("{ctx}[{i}] weighted: {e}"));
                    let c = c.as_ref().unwrap_or_else(|e| panic!("{ctx}[{i}]: {e}"));
                    assert_eq!(g.precision(), c.precision(), "{ctx}[{i}]: lane");
                    assert_eq!(
                        bits(g.materialize_f64()),
                        bits(c.materialize_f64()),
                        "{ctx}[{i}]: value bits"
                    );
                    assert_eq!(
                        g.l2_loss().to_bits(),
                        c.l2_loss().to_bits(),
                        "{ctx}[{i}]: loss bits"
                    );
                    assert_eq!(
                        g.diag().iterations,
                        c.diag().iterations,
                        "{ctx}[{i}]: iterations"
                    );
                    assert_eq!(g.diag().nnz, c.diag().nnz, "{ctx}[{i}]: nnz");
                }
            }
        }
    }
}

#[test]
fn caching_facade_is_bitwise_invisible_for_every_method_plan_lane() {
    // ISSUE-8 acceptance pin: a memoizing facade serving a repeated
    // request must be indistinguishable — bit for bit — from the
    // stateless facade, across every method, both precision lanes, and
    // the three single-vector plans the memo covers (one-shot,
    // target-count, warm sweep). Both the memo-fill run and the pure
    // replay run are compared against a cold stateless solve.
    let data = clustered(64, 20);
    let plans: [(&str, fn(QuantRequest) -> QuantRequest); 3] = [
        ("one-shot", |r| r),
        ("target-count", |r| r.target_count(5)),
        ("warm-sweep", |r| r.sweep(vec![0.02, 0.01, 0.005])),
    ];
    let bits = |v: Vec<f64>| -> Vec<u64> { v.into_iter().map(f64::to_bits).collect() };
    for method in QuantMethod::ALL {
        for lane in [Precision::F64, Precision::F32] {
            for (plan_name, plan) in plans {
                let ctx = format!("{method:?}/{lane:?}/{plan_name}");
                let build = || {
                    plan(
                        QuantRequest::slice(&data)
                            .method(method)
                            .options(QuantOptions { precision: lane, ..test_opts() }),
                    )
                };
                let cold = Quantizer::new().run(&build()).unwrap();
                let memo = Quantizer::caching(64);
                let fill = memo.run(&build()).unwrap();
                let replay = memo.run(&build()).unwrap();
                for (stage, got) in [("fill", &fill), ("replay", &replay)] {
                    assert_eq!(got.items.len(), cold.items.len(), "{ctx}/{stage}: item count");
                    for (i, (g, c)) in got.items.iter().zip(&cold.items).enumerate() {
                        let g = g.as_ref().unwrap_or_else(|e| panic!("{ctx}/{stage}[{i}]: {e}"));
                        let c = c.as_ref().unwrap_or_else(|e| panic!("{ctx}[{i}]: {e}"));
                        assert_eq!(g.precision(), c.precision(), "{ctx}/{stage}[{i}]: lane");
                        assert_eq!(
                            bits(g.materialize_f64()),
                            bits(c.materialize_f64()),
                            "{ctx}/{stage}[{i}]: value bits"
                        );
                        assert_eq!(
                            g.l2_loss().to_bits(),
                            c.l2_loss().to_bits(),
                            "{ctx}/{stage}[{i}]: loss bits"
                        );
                        assert_eq!(
                            g.diag().iterations,
                            c.diag().iterations,
                            "{ctx}/{stage}[{i}]: iterations"
                        );
                        assert_eq!(g.diag().nnz, c.diag().nnz, "{ctx}/{stage}[{i}]: nnz");
                    }
                }
            }
        }
    }
}
