//! Integration: the AOT artifacts (python/jax/pallas → HLO text) load,
//! compile and execute on the PJRT runtime, and their numerics match the
//! native Rust engines. Requires `make artifacts`; tests skip (with a
//! loud message) when the artifact directory is absent so plain
//! `cargo test` works on a fresh checkout.

use sqlsq::coordinator::router;
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{self, unique::UniqueDecomp, vmatrix::VBasis, QuantMethod, QuantOptions};
use sqlsq::runtime::Executor;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/manifest.json — run `make artifacts`");
        None
    }
}

fn sample(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.uniform(0.0, 1.0)).collect()
}

#[test]
fn executor_opens_and_reports_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let ex = Executor::open(&dir).unwrap();
    assert!(ex.max_lasso_m() >= 1024);
    assert!(ex.lasso_epochs_per_call() >= 1);
    assert_eq!(ex.platform(), "cpu");
}

#[test]
fn runtime_lasso_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::open(&dir).unwrap();
    for (seed, n) in [(1u64, 40), (2, 150), (3, 500)] {
        let data = sample(seed, n);
        let (native_loss, runtime_loss) =
            router::check_lasso_equivalence(&mut ex, &data, 0.01).unwrap();
        // End-to-end sanity (the strict per-epoch numerics check is
        // `runtime_lasso_alpha_matches_native_epochs`). Native and runtime
        // stop at different support-patience granularities (10 epochs vs
        // 2×8-epoch calls), so supports — and refit losses — can differ
        // slightly; bound the divergence rather than demanding equality.
        let denom = native_loss.abs().max(1e-9);
        assert!(
            (native_loss - runtime_loss).abs() / denom < 0.20
                || (native_loss - runtime_loss).abs() < 1e-6,
            "seed={seed} n={n}: native {native_loss} vs runtime {runtime_loss}"
        );
    }
}

#[test]
fn runtime_lasso_alpha_matches_native_epochs() {
    // Drive the artifact one call at a time and compare α against the
    // native structured solver run for the same number of epochs.
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::open(&dir).unwrap();
    let data = sample(11, 60);
    let u = UniqueDecomp::new(&data).unwrap();
    let basis = VBasis::new(&u.values);
    let w32: Vec<f32> = u.values.iter().map(|&x| x as f32).collect();
    let d32: Vec<f32> = basis.diffs().iter().map(|&x| x as f32).collect();

    let epc = ex.lasso_epochs_per_call();
    let rt = ex.lasso_solve(&w32, &d32, 0.05, 0.0, 1, 0.0).unwrap();
    assert_eq!(rt.calls, 1);

    let cfg = quant::lasso::LassoConfig {
        lambda1: 0.05,
        max_epochs: epc,
        tol: 0.0,
        ..Default::default()
    };
    let native = quant::lasso::solve(&basis, &u.values, &cfg, None).unwrap();
    assert_eq!(native.epochs, epc);
    for (i, (a32, a64)) in rt.alpha.iter().zip(&native.alpha).enumerate() {
        assert!(
            (*a32 as f64 - a64).abs() < 5e-3,
            "α[{i}]: runtime {a32} vs native {a64}"
        );
    }
}

#[test]
fn runtime_kmeans_converges_like_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::open(&dir).unwrap();
    // Three tight groups; any sane Lloyd run finds them.
    let mut data = Vec::new();
    let mut rng = Pcg32::seeded(5);
    for c in [0.1f64, 0.5, 0.9] {
        for _ in 0..40 {
            data.push(c + rng.uniform(-0.01, 0.01));
        }
    }
    let pts: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let cw = vec![1.0f32; pts.len()];
    let cen0 = vec![0.2f32, 0.6, 0.8];
    let cen = ex.kmeans_lloyd(&pts, &cw, &cen0, 10).unwrap();
    assert_eq!(cen.len(), 3);
    assert!((cen[0] - 0.1).abs() < 0.02, "{cen:?}");
    assert!((cen[1] - 0.5).abs() < 0.02, "{cen:?}");
    assert!((cen[2] - 0.9).abs() < 0.02, "{cen:?}");
}

#[test]
fn runtime_gmm_finds_separated_modes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::open(&dir).unwrap();
    let mut rng = Pcg32::seeded(6);
    let mut pts = Vec::new();
    for c in [10.0f32, 90.0] {
        for _ in 0..128 {
            pts.push(c + rng.normal_with(0.0, 1.0) as f32);
        }
    }
    let cw = vec![1.0f32; pts.len()];
    let mu0 = vec![30.0f32, 60.0];
    let var0 = vec![200.0f32, 200.0];
    let pi0 = vec![0.5f32, 0.5];
    let (mu, var, pi) = ex.gmm_em(&pts, &cw, &mu0, &var0, &pi0, 1e-4, 10).unwrap();
    assert!((mu[0] - 10.0).abs() < 1.0, "mu={mu:?}");
    assert!((mu[1] - 90.0).abs() < 1.0, "mu={mu:?}");
    assert!(var[0] < 5.0 && var[1] < 5.0, "var={var:?}");
    assert!((pi[0] - 0.5).abs() < 0.05, "pi={pi:?}");
    assert!((pi.iter().sum::<f32>() - 1.0).abs() < 1e-4);
}

#[test]
fn coordinator_serves_gmm_via_runtime() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = sqlsq::config::Config {
        workers: 1,
        engine: sqlsq::config::Engine::Auto,
        artifacts_dir: dir,
        ..Default::default()
    };
    let coord = sqlsq::coordinator::Coordinator::start(cfg).unwrap();
    let data = sample(10, 200);
    let res = coord
        .quantize_blocking(
            data.clone(),
            QuantMethod::Gmm,
            QuantOptions { target_values: 8, ..Default::default() },
        )
        .unwrap();
    let out = res.outcome.expect("runtime gmm job must succeed");
    assert_eq!(out.materialize().len(), data.len());
    assert!(out.distinct_values() <= 8);
    assert_eq!(res.served_by.label(), "runtime");
    coord.shutdown();
}

#[test]
fn runtime_mlp_matches_native_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = Executor::open(&dir).unwrap();
    let mlp = sqlsq::nn::mlp::Mlp::paper_arch(3);
    // A batch of canonical digits.
    let mut rows = Vec::new();
    for d in 0..10 {
        rows.push(sqlsq::data::synth_digits::canonical_digit(d).pixels);
    }
    let rows_n = rows.len();
    let x32: Vec<f32> = rows.iter().flatten().map(|&v| v as f32).collect();
    let params32: Vec<(Vec<f32>, Vec<f32>)> = mlp
        .layers
        .iter()
        .map(|l| {
            (
                l.w.data().iter().map(|&v| v as f32).collect(),
                l.b.iter().map(|&v| v as f32).collect(),
            )
        })
        .collect();
    let params_ref: Vec<(&[f32], &[f32])> =
        params32.iter().map(|(w, b)| (w.as_slice(), b.as_slice())).collect();
    let logits32 = ex.mlp_forward(&x32, rows_n, 784, 10, &params_ref).unwrap();
    assert_eq!(logits32.len(), rows_n * 10);

    // Native forward for comparison.
    let mut xm = sqlsq::linalg::matrix::Matrix::zeros(rows_n, 784);
    for (i, r) in rows.iter().enumerate() {
        xm.row_mut(i).copy_from_slice(r);
    }
    let native = mlp.infer(&xm).unwrap();
    for i in 0..rows_n {
        for j in 0..10 {
            let a = logits32[i * 10 + j] as f64;
            let b = native[(i, j)];
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "logit[{i},{j}]: runtime {a} vs native {b}"
            );
        }
    }
    // And the argmax predictions agree.
    for i in 0..rows_n {
        let rt_pred = (0..10)
            .max_by(|&a, &b| logits32[i * 10 + a].partial_cmp(&logits32[i * 10 + b]).unwrap())
            .unwrap();
        let nat_row = native.row(i);
        let nat_pred = (0..10)
            .max_by(|&a, &b| nat_row[a].partial_cmp(&nat_row[b]).unwrap())
            .unwrap();
        assert_eq!(rt_pred, nat_pred, "prediction mismatch on row {i}");
    }
}

#[test]
fn coordinator_auto_policy_serves_via_runtime() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = sqlsq::config::Config {
        workers: 2,
        engine: sqlsq::config::Engine::Auto,
        artifacts_dir: dir,
        ..Default::default()
    };
    let coord = sqlsq::coordinator::Coordinator::start(cfg).unwrap();
    let data = sample(9, 200);
    let res = coord
        .quantize_blocking(
            data.clone(),
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.02, ..Default::default() },
        )
        .unwrap();
    let out = res.outcome.expect("runtime-lane job must succeed");
    assert_eq!(out.materialize().len(), data.len());
    assert_eq!(res.served_by.label(), "runtime");
    // Native engines still work side by side.
    let res2 = coord
        .quantize_blocking(
            data,
            QuantMethod::ClusterLs,
            QuantOptions { target_values: 8, ..Default::default() },
        )
        .unwrap();
    assert!(res2.is_ok());
    assert_eq!(res2.served_by.label(), "native");
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 2);
    assert!(snap.served_runtime >= 1);
}
