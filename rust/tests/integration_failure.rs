//! Failure injection: corrupted artifacts, bad manifests, and overload
//! must degrade loudly-but-cleanly — errors, fallbacks, and load shedding
//! rather than panics or wrong numbers.

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::Coordinator;
use sqlsq::quant::{QuantMethod, QuantOptions};
use sqlsq::runtime::{artifact, Executor};
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sqlsq_failtest_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_errors_cleanly() {
    let dir = tmpdir("missing");
    let err = match Executor::open(&dir) {
        Err(e) => e,
        Ok(_) => panic!("opening an empty artifact dir must fail"),
    };
    assert!(err.to_string().contains("make artifacts"), "err: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_manifest_json_errors() {
    let dir = tmpdir("badjson");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(artifact::load_manifest(&dir).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn manifest_with_missing_hlo_file_fails_at_execute() {
    let dir = tmpdir("missing_hlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "artifacts": [
            {"name": "lasso_cd_m64", "file": "nonexistent.hlo.txt",
             "inputs": [
                {"shape": [64], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"},
                {"shape": [2], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"}],
             "meta": {"kind": "lasso_cd", "m": 64, "epochs_per_call": 8}}
        ]}"#,
    )
    .unwrap();
    let mut ex = Executor::open(&dir).unwrap(); // opening is lazy
    let w = vec![0.5f32; 8];
    let d = vec![0.1f32; 8];
    let err = ex.lasso_solve(&w, &d, 0.01, 0.0, 2, 1e-6).unwrap_err();
    assert!(err.to_string().contains("nonexistent"), "err: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_hlo_text_fails_to_parse() {
    let dir = tmpdir("truncated");
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule garbage {{{").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "artifacts": [
            {"name": "lasso_cd_m64", "file": "broken.hlo.txt",
             "inputs": [
                {"shape": [64], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"},
                {"shape": [2], "dtype": "float32"},
                {"shape": [64], "dtype": "float32"}],
             "meta": {"kind": "lasso_cd", "m": 64, "epochs_per_call": 8}}
        ]}"#,
    )
    .unwrap();
    let mut ex = Executor::open(&dir).unwrap();
    let w = vec![0.5f32; 8];
    let d = vec![0.1f32; 8];
    assert!(ex.lasso_solve(&w, &d, 0.01, 0.0, 2, 1e-6).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn auto_coordinator_with_broken_artifacts_falls_back_to_native() {
    // Manifest advertises a bucket, but the HLO is broken: the runtime
    // lane must fail per job and Auto must still serve natively.
    let dir = tmpdir("auto_fallback");
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule nope").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "artifacts": [
            {"name": "lasso_cd_m1024", "file": "broken.hlo.txt",
             "inputs": [
                {"shape": [1024], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"},
                {"shape": [2], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"}],
             "meta": {"kind": "lasso_cd", "m": 1024, "epochs_per_call": 8}}
        ]}"#,
    )
    .unwrap();
    let coord = Coordinator::start(Config {
        workers: 1,
        runtime_lanes: 1,
        engine: Engine::Auto,
        artifacts_dir: dir.clone(),
        ..Default::default()
    })
    .unwrap();
    let data: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin()).collect();
    let res = coord
        .quantize_blocking(
            data.clone(),
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.01, ..Default::default() },
        )
        .unwrap();
    let out = res.outcome.expect("auto fallback must succeed");
    assert_eq!(out.materialize().len(), data.len());
    assert_eq!(res.served_by.label(), "native");
    coord.shutdown();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn runtime_policy_with_broken_artifacts_fails_jobs_loudly() {
    let dir = tmpdir("strict_runtime");
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule nope").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "artifacts": [
            {"name": "lasso_cd_m1024", "file": "broken.hlo.txt",
             "inputs": [
                {"shape": [1024], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"},
                {"shape": [2], "dtype": "float32"},
                {"shape": [1024], "dtype": "float32"}],
             "meta": {"kind": "lasso_cd", "m": 1024, "epochs_per_call": 8}}
        ]}"#,
    )
    .unwrap();
    let coord = Coordinator::start(Config {
        workers: 1,
        runtime_lanes: 1,
        engine: Engine::Runtime,
        artifacts_dir: dir.clone(),
        ..Default::default()
    })
    .unwrap();
    let data: Vec<f64> = (0..50).map(|i| i as f64).collect();
    let res = coord
        .quantize_blocking(
            data,
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.01, ..Default::default() },
        )
        .unwrap();
    assert!(!res.is_ok(), "strict runtime policy must surface the failure");
    let snap = coord.shutdown();
    assert_eq!(snap.failed, 1);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn wrong_input_shape_rejected_by_registry() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let mut reg = sqlsq::runtime::Registry::open(&dir).unwrap();
    // lasso_cd_m64 wants five inputs with [64]-shapes; feed garbage.
    let bad = vec![0.0f32; 3];
    let err = reg
        .execute_f32("lasso_cd_m64", &[&bad, &bad, &bad, &bad, &bad])
        .unwrap_err();
    assert!(err.to_string().contains("elements"), "err: {err}");
    let err2 = reg.execute_f32("lasso_cd_m64", &[&bad]).unwrap_err();
    assert!(err2.to_string().contains("inputs"), "err: {err2}");
    assert!(reg.execute_f32("no_such_artifact", &[]).is_err());
}
