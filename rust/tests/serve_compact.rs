//! The codebook-native serve path, pinned three ways:
//!
//! 1. compact results through the coordinator are **bitwise-identical**
//!    to the PR-4 derive-at-edge path (run the legacy engine, materialize
//!    a full vector, re-encode it at the edge) — on both precision lanes;
//! 2. the compression accounting (`bits_per_value`, `index_entropy`,
//!    byte counts) agrees with a brute-force recomputation from the
//!    materialized vector — a property checked across seeds, methods and
//!    lanes;
//! 3. the batch×sweep plan returns B×K codebook items through one
//!    submit, each bitwise-identical to the legacy per-vector sweep.

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::Coordinator;
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{
    self, Codebook, CompressionStats, Precision, QuantMethod, QuantOptions, QuantRequest,
    Quantizer,
};

fn clustered(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let center = [0.1, 0.35, 0.6, 0.9][i % 4];
        // Round so repeats occur (multiplicities > 1).
        v.push(((center + rng.normal_with(0.0, 0.02)) * 200.0).round() / 200.0);
    }
    v
}

fn narrowed(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

fn native_coord() -> Coordinator {
    Coordinator::start(Config {
        workers: 2,
        queue_capacity: 64,
        max_batch: 8,
        batch_wait_us: 100,
        engine: Engine::Native,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn coordinator_compact_results_match_derive_at_edge_f64() {
    let c = native_coord();
    for (seed, method) in [
        (1u64, QuantMethod::KMeans),
        (2, QuantMethod::L1LeastSquare),
        (3, QuantMethod::ClusterLs),
        (4, QuantMethod::IterativeL1),
    ] {
        let data = clustered(80, seed);
        let opts = QuantOptions {
            lambda1: 0.02,
            target_values: 4,
            seed,
            ..Default::default()
        };
        // The PR-4 path: legacy engine output (full vector), codebook
        // derived at the edge by re-encoding the materialized values.
        let legacy = quant::quantize(&data, method, &opts).unwrap();
        let derived = Codebook::from_output(&legacy).unwrap();

        // The compact-native path: the coordinator ships the codebook the
        // engine finalize built; no full vector crosses the respond
        // channel.
        let res = c.quantize_blocking(data.clone(), method, opts).unwrap();
        let out = res.outcome.expect("job must succeed");
        assert_eq!(out.precision(), Precision::F64, "{method:?}");
        assert_eq!(out.codebook().levels, derived.levels, "{method:?}: levels");
        assert_eq!(out.codebook().indices, derived.indices, "{method:?}: indices");
        assert_eq!(out.materialize(), legacy.values, "{method:?}: edge decode");
        assert_eq!(out.l2_loss().to_bits(), legacy.l2_loss.to_bits(), "{method:?}: loss");
        assert_eq!(out.clamped(), legacy.clamped, "{method:?}: clamp count");
        assert_eq!(out.diag().nnz, legacy.diag.nnz, "{method:?}: nnz");
        assert_eq!(out.diag().iterations, legacy.diag.iterations, "{method:?}");
    }
    c.shutdown();
}

#[test]
fn coordinator_compact_results_match_derive_at_edge_f32() {
    let c = native_coord();
    for (seed, method) in [(11u64, QuantMethod::L1LeastSquare), (12, QuantMethod::KMeans)] {
        let data32 = narrowed(&clustered(70, seed));
        let opts = QuantOptions { lambda1: 0.03, target_values: 4, seed, ..Default::default() };
        // PR-4 edge path for f32 payloads: the result surface widened
        // first, then re-encoded.
        let legacy_wide = quant::quantize_f32(&data32, method, &opts).unwrap().widen();
        let derived = Codebook::from_output(&legacy_wide).unwrap();

        let res = c.quantize_blocking_f32(data32.clone(), method, opts).unwrap();
        let out = res.outcome.expect("f32 job must succeed");
        assert_eq!(out.precision(), Precision::F32, "{method:?}: stays narrow");
        assert_eq!(out.codebook().levels, derived.levels, "{method:?}: levels");
        assert_eq!(out.codebook().indices, derived.indices, "{method:?}: indices");
        assert_eq!(out.materialize(), legacy_wide.values, "{method:?}: edge decode");
        assert_eq!(out.l2_loss().to_bits(), legacy_wide.l2_loss.to_bits(), "{method:?}");
    }
    c.shutdown();
}

/// Brute-force compression accounting from a materialized vector: the
/// independent reference the serve path's stats must agree with.
fn bruteforce_stats(values: &[f64], requested: usize, dense_elem_bytes: usize) -> CompressionStats {
    let mut levels: Vec<f64> = values.to_vec();
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    levels.dedup();
    let k = levels.len();
    let bits_per_index = (usize::BITS - (k - 1).leading_zeros()).max(1);
    // The compact wire pays the honest packed width: zero index bits for
    // a single-level (constant) payload, ⌈log₂ k⌉ otherwise.
    let packed_bits = if k <= 1 { 0 } else { usize::BITS - (k - 1).leading_zeros() };
    let idx_bits = values.len() * packed_bits as usize;
    let compact = idx_bits.div_ceil(8) + k * 4;
    let n = values.len() as f64;
    let entropy: f64 = levels
        .iter()
        .map(|l| values.iter().filter(|&&v| v == *l).count())
        .filter(|&c| c > 0)
        .map(|c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum();
    let dense = values.len() * dense_elem_bytes;
    CompressionStats {
        n: values.len(),
        levels_achieved: k,
        levels_requested: requested,
        bits_per_index,
        bits_per_idx_stored: 32,
        bits_per_idx_packed: packed_bits,
        bits_per_value: compact as f64 * 8.0 / n,
        index_entropy: entropy,
        entropy_coded_bytes: (n * entropy / 8.0).ceil() as usize + k * 4,
        compact_bytes: compact,
        dense_bytes: dense,
        byte_ratio: dense as f64 / compact as f64,
    }
}

#[test]
fn compression_stats_agree_with_bruteforce_recompute() {
    for seed in 0..8u64 {
        let method = [QuantMethod::KMeans, QuantMethod::L1LeastSquare, QuantMethod::ClusterLs]
            [seed as usize % 3];
        let data = clustered(60 + 11 * seed as usize, 200 + seed);
        let requested = 3 + (seed as usize % 4);
        let opts = QuantOptions {
            lambda1: 0.02,
            target_values: requested,
            seed,
            ..Default::default()
        };

        // f64 lane.
        let req = QuantRequest::vector(data.clone()).method(method).options(opts.clone());
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let got = item.compression(requested);
        let want = bruteforce_stats(&item.materialize_f64(), requested, 8);
        assert_eq!(got.n, want.n, "seed {seed}");
        assert_eq!(got.levels_achieved, want.levels_achieved, "seed {seed}");
        assert_eq!(got.levels_requested, want.levels_requested, "seed {seed}");
        assert_eq!(got.bits_per_index, want.bits_per_index, "seed {seed}");
        assert_eq!(got.bits_per_idx_stored, 32, "seed {seed}: dense plane stores u32");
        assert_eq!(got.bits_per_idx_packed, want.bits_per_idx_packed, "seed {seed}");
        assert_eq!(got.compact_bytes, want.compact_bytes, "seed {seed}");
        assert_eq!(got.dense_bytes, want.dense_bytes, "seed {seed}");
        assert!((got.bits_per_value - want.bits_per_value).abs() < 1e-12, "seed {seed}");
        assert!((got.index_entropy - want.index_entropy).abs() < 1e-9, "seed {seed}");
        assert!((got.byte_ratio - want.byte_ratio).abs() < 1e-12, "seed {seed}");

        // f32 lane: same property, dense baseline is 4 bytes/element.
        let data32 = narrowed(&data);
        let req32 = QuantRequest::vector_f32(data32).method(method).options(opts);
        let item32 = Quantizer::new().run(&req32).unwrap().into_single().unwrap();
        let got32 = item32.compression(requested);
        let want32 = bruteforce_stats(&item32.materialize_f64(), requested, 4);
        assert_eq!(got32.levels_achieved, want32.levels_achieved, "seed {seed} f32");
        assert_eq!(got32.compact_bytes, want32.compact_bytes, "seed {seed} f32");
        assert_eq!(got32.dense_bytes, want32.dense_bytes, "seed {seed} f32");
        assert!((got32.index_entropy - want32.index_entropy).abs() < 1e-9, "seed {seed} f32");
    }
}

#[test]
fn coordinator_job_stats_agree_with_bruteforce_recompute() {
    let c = native_coord();
    let data = clustered(90, 77);
    let res = c
        .quantize_blocking(
            data,
            QuantMethod::KMeans,
            QuantOptions { target_values: 5, seed: 7, ..Default::default() },
        )
        .unwrap();
    let out = res.outcome.unwrap();
    let got = out.compression();
    let want = bruteforce_stats(&out.materialize(), 5, 8);
    assert_eq!(got.levels_achieved, want.levels_achieved);
    assert_eq!(got.compact_bytes, want.compact_bytes);
    assert!((got.index_entropy - want.index_entropy).abs() < 1e-9);
    assert!((got.bits_per_value - want.bits_per_value).abs() < 1e-12);
    c.shutdown();
}

#[test]
fn batch_sweep_returns_bxk_codebook_items_through_one_submit() {
    let vectors = vec![clustered(60, 50), clustered(50, 51), clustered(70, 52)];
    let lambdas = vec![1e-4, 1e-3, 1e-2, 1e-1];
    let (b, k) = (vectors.len(), lambdas.len());

    // One submit: a single request through the Quantizer front door.
    let req = QuantRequest::batch(vectors.clone())
        .method(QuantMethod::L1LeastSquare)
        .sweep(lambdas.clone());
    let resp = Quantizer::new().run(&req).unwrap();
    assert_eq!(resp.len(), b * k, "B×K items");

    // Reference: the legacy per-vector warm-started sweep.
    for (bi, w) in vectors.iter().enumerate() {
        let prep = quant::PreparedInput::new(w).unwrap();
        let legacy = quant::quantize_sweep(
            &prep,
            QuantMethod::L1LeastSquare,
            &lambdas,
            &QuantOptions::default(),
        )
        .unwrap();
        for (ki, want) in legacy.iter().enumerate() {
            let item = resp.items[bi * k + ki].as_ref().unwrap();
            let q = item.as_f64().expect("f64 lane");
            assert!(
                q.values().is_none(),
                "batch×sweep items stay compact (vec {bi} λ#{ki})"
            );
            assert_eq!(q.codebook.levels, want.levels, "vec {bi} λ#{ki}: levels");
            assert_eq!(q.materialize(), want.values, "vec {bi} λ#{ki}: decode");
            assert_eq!(q.l2_loss.to_bits(), want.l2_loss.to_bits(), "vec {bi} λ#{ki}");
            assert_eq!(item.diag().lambda1, lambdas[ki], "vec {bi} λ#{ki}: λ");
        }
    }

    // Aggregate accounting over the whole response works.
    let agg = resp.compression(16).expect("all items succeeded");
    assert_eq!(agg.n, vectors.iter().map(Vec::len).sum::<usize>() * k);
}
