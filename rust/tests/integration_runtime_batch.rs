//! Integration: the runtime serve path — batching, fan-out, Auto
//! fallback, f32 widening, metrics — under test with the deterministic
//! [`ShadowBackend`]. No PJRT artifacts required: everything here runs
//! under plain `cargo test` in CI.

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::router::{self, Router};
use sqlsq::coordinator::server::serve_batch_runtime;
use sqlsq::coordinator::{BackendFactory, Coordinator, Job, JobResult, Metrics, Payload, ServedBy};
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{QuantMethod, QuantOptions};
use sqlsq::runtime::{BackendKind, ExecutorBackend, ShadowBackend};
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn sample(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.uniform(0.0, 1.0)).collect()
}

fn shadow_cfg(runtime_fanout: usize) -> Config {
    Config {
        workers: 1,
        runtime_lanes: 1,
        queue_capacity: 256,
        max_batch: 32,
        batch_wait_us: 3000,
        engine: Engine::Auto,
        runtime_backend: BackendKind::Shadow,
        runtime_fanout,
        ..Default::default()
    }
}

/// A runtime-capable job mix (methods × sizes × λ/k) that fits the
/// default shadow buckets.
fn job_mix(count: usize) -> Vec<(Vec<f64>, QuantMethod, QuantOptions)> {
    (0..count as u64)
        .map(|i| {
            let n = [40usize, 200, 600][((i / 3) % 3) as usize];
            let method = [QuantMethod::L1LeastSquare, QuantMethod::KMeans, QuantMethod::Gmm]
                [(i % 3) as usize];
            let opts = QuantOptions {
                lambda1: 0.02,
                target_values: 8,
                seed: i,
                ..Default::default()
            };
            (sample(1000 + i, n), method, opts)
        })
        .collect()
}

/// Build a raw Job + its result receiver (for driving the lane logic
/// directly, outside a coordinator).
fn raw_job(
    id: u64,
    data: Payload,
    method: QuantMethod,
    opts: QuantOptions,
) -> (Job, mpsc::Receiver<JobResult>) {
    let (tx, rx) = mpsc::channel();
    (
        Job {
            id,
            data,
            method,
            opts,
            weights: None,
            submitted: Instant::now(),
            respond: tx,
            cache: None,
        },
        rx,
    )
}

#[test]
fn runtime_batch_results_match_per_job_dispatch() {
    // Jobs served through the batched, fanned runtime lane must be
    // bitwise-identical to direct per-job dispatch_runtime calls.
    let coord = Coordinator::start(shadow_cfg(4)).unwrap();
    let mix = job_mix(24);
    let mut rxs = Vec::new();
    for (data, method, opts) in &mix {
        let (_, rx) = coord.submit(data.clone(), *method, opts.clone()).unwrap();
        rxs.push(rx);
    }
    let mut reference = ShadowBackend::new();
    for ((data, method, opts), rx) in mix.iter().zip(rxs) {
        let res = rx.recv().unwrap();
        assert_eq!(res.served_by, ServedBy::Runtime, "{method:?} must serve on the lane");
        let got = res.outcome.expect("runtime job must succeed");
        let direct = router::dispatch_runtime(&mut reference, data, *method, opts).unwrap();
        // Compact-native both ways: compare the codebooks themselves, and
        // the materialized edge view.
        assert_eq!(got.codebook(), direct.codebook, "{method:?}: batched lane diverged");
        assert_eq!(got.materialize(), direct.materialize(), "{method:?}");
        assert_eq!(got.l2_loss().to_bits(), direct.l2_loss.to_bits());
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.served_runtime, 24);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.lanes_degraded, 0);
}

#[test]
fn runtime_batch_fans_across_sub_lanes_and_matches_serial() {
    // Acceptance: one drained batch executes on ≥ 2 sub-lanes when
    // runtime_fanout ≥ 2 (thread-id capture), with results
    // bitwise-identical to the serial path.
    let probe = ShadowBackend::with_capture();
    let backend_src = probe.clone();
    let factory: BackendFactory = Arc::new(move |_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(backend_src.clone()))
    });
    let coord = Coordinator::start_with_backend_factory(shadow_cfg(4), factory).unwrap();
    let mix = job_mix(32);
    let mut rxs = Vec::new();
    for (data, method, opts) in &mix {
        let (_, rx) = coord.submit(data.clone(), *method, opts.clone()).unwrap();
        rxs.push(rx);
    }
    let fanned: Vec<_> = rxs
        .into_iter()
        .map(|rx| {
            let res = rx.recv().unwrap();
            assert_eq!(res.served_by, ServedBy::Runtime);
            res.outcome.expect("fanned job must succeed")
        })
        .collect();
    coord.shutdown();
    assert!(
        probe.distinct_call_threads() >= 2,
        "expected kernel calls on ≥ 2 sub-lanes, saw {} (calls: {})",
        probe.distinct_call_threads(),
        probe.calls().len()
    );

    // Serial reference: same submissions through a fanout-1 coordinator.
    let coord1 = Coordinator::start(shadow_cfg(1)).unwrap();
    let mut rxs1 = Vec::new();
    for (data, method, opts) in &mix {
        let (_, rx) = coord1.submit(data.clone(), *method, opts.clone()).unwrap();
        rxs1.push(rx);
    }
    for (fanned_out, rx) in fanned.iter().zip(rxs1) {
        let serial_out = rx.recv().unwrap().outcome.expect("serial job must succeed");
        assert_eq!(fanned_out.codebook(), serial_out.codebook(), "fan-out changed a result");
        assert_eq!(fanned_out.l2_loss().to_bits(), serial_out.l2_loss().to_bits());
    }
    coord1.shutdown();
}

#[test]
fn auto_policy_serves_failed_runtime_jobs_native() {
    // Every kernel call fails; Auto must fall back per job, report
    // ServedBy::Native, and count zero failures.
    let factory: BackendFactory = Arc::new(|_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(ShadowBackend::failing("injected kernel failure")))
    });
    let coord = Coordinator::start_with_backend_factory(shadow_cfg(2), factory).unwrap();
    let mix = job_mix(9);
    let mut rxs = Vec::new();
    for (data, method, opts) in &mix {
        let (_, rx) = coord.submit(data.clone(), *method, opts.clone()).unwrap();
        rxs.push(rx);
    }
    for ((data, method, opts), rx) in mix.iter().zip(rxs) {
        let res = rx.recv().unwrap();
        assert_eq!(res.served_by, ServedBy::Native, "fallback must report native");
        let got = res.outcome.expect("fallback must succeed");
        let direct = sqlsq::quant::quantize(data, *method, opts).unwrap();
        assert_eq!(
            got.materialize(),
            direct.values,
            "{method:?}: fallback diverged from native"
        );
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 9);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.served_native, 9, "all jobs fell back");
    assert_eq!(snap.served_runtime, 0);
    assert_eq!(snap.lanes_degraded, 0, "the lane itself opened fine");
}

#[test]
fn strict_runtime_policy_surfaces_injected_failures() {
    let factory: BackendFactory = Arc::new(|_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(ShadowBackend::failing("injected kernel failure")))
    });
    let cfg = Config { engine: Engine::Runtime, ..shadow_cfg(2) };
    let coord = Coordinator::start_with_backend_factory(cfg, factory).unwrap();
    let res = coord
        .quantize_blocking(
            sample(7, 100),
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.02, ..Default::default() },
        )
        .unwrap();
    assert!(!res.is_ok(), "strict policy must surface the failure");
    assert_eq!(res.served_by, ServedBy::Runtime);
    assert!(res.outcome.unwrap_err().contains("injected"), "error text must survive");
    let snap = coord.shutdown();
    assert_eq!(snap.failed, 1);
}

#[test]
fn lane_with_failing_backend_open_degrades_and_reroutes_native() {
    // Regression for the open-failure path: the lane must count itself
    // degraded and (under Auto) serve its pops natively instead of
    // erroring every job.
    let factory: BackendFactory = Arc::new(|_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Err(sqlsq::Error::Runtime("backend open refused (injected)".into()))
    });
    let coord = Coordinator::start_with_backend_factory(shadow_cfg(2), factory).unwrap();
    let mix = job_mix(9);
    let mut rxs = Vec::new();
    for (data, method, opts) in &mix {
        let (_, rx) = coord.submit(data.clone(), *method, opts.clone()).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let res = rx.recv().unwrap();
        assert!(res.is_ok(), "degraded lane must still serve jobs under Auto");
        assert_eq!(res.served_by, ServedBy::Native);
    }
    let snap = coord.shutdown();
    assert_eq!(snap.lanes_degraded, 1);
    assert_eq!(snap.completed, 9);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.served_native, 9);
}

#[test]
fn strict_policy_degraded_lane_fails_jobs_loudly() {
    let factory: BackendFactory = Arc::new(|_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Err(sqlsq::Error::Runtime("backend open refused (injected)".into()))
    });
    let cfg = Config { engine: Engine::Runtime, ..shadow_cfg(1) };
    let coord = Coordinator::start_with_backend_factory(cfg, factory).unwrap();
    let res = coord
        .quantize_blocking(
            sample(8, 100),
            QuantMethod::KMeans,
            QuantOptions { target_values: 8, ..Default::default() },
        )
        .unwrap();
    assert!(!res.is_ok());
    assert_eq!(res.served_by, ServedBy::Runtime);
    let snap = coord.shutdown();
    assert_eq!(snap.lanes_degraded, 1);
    assert_eq!(snap.failed, 1);
}

#[test]
fn custom_bucket_factory_routes_by_its_own_info() {
    // A factory whose shadow backend has tiny buckets must be paired
    // with its own capability table (start_with_backend_factory_and_info)
    // so admission routing agrees with the lanes: big jobs stay native
    // instead of paying a doomed runtime attempt (or failing outright
    // under the strict policy).
    use sqlsq::runtime::ShadowBuckets;
    let tiny = ShadowBuckets {
        lasso: vec![64],
        kmeans: vec![(64, 8)],
        gmm: vec![(64, 8)],
        ..ShadowBuckets::default()
    };
    let backend = ShadowBackend::with_buckets(tiny);
    let info = backend.info();
    let factory: BackendFactory = Arc::new(move |_| -> sqlsq::Result<Box<dyn ExecutorBackend>> {
        Ok(Box::new(backend.clone()))
    });
    let coord =
        Coordinator::start_with_backend_factory_and_info(shadow_cfg(2), factory, Some(info))
            .unwrap();
    let opts = QuantOptions { lambda1: 0.02, target_values: 8, ..Default::default() };
    // Fits the tiny bucket → runtime lane.
    let small = coord
        .quantize_blocking(sample(31, 50), QuantMethod::L1LeastSquare, opts.clone())
        .unwrap();
    assert!(small.is_ok());
    assert_eq!(small.served_by, ServedBy::Runtime);
    // Exceeds every tiny bucket → routed native at admission, no
    // runtime attempt at all.
    let big = coord
        .quantize_blocking(sample(32, 500), QuantMethod::L1LeastSquare, opts)
        .unwrap();
    assert!(big.is_ok());
    assert_eq!(big.served_by, ServedBy::Native);
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.served_runtime, 1);
    assert_eq!(snap.served_native, 1);
    assert_eq!(snap.failed, 0);
}

#[test]
fn f32_payloads_widen_defensively_on_the_runtime_lane() {
    // Admission keeps f32 payloads native, so drive the lane logic
    // directly to cover serve_batch_runtime's widening branch: an f32
    // job must produce exactly the result of runtime-dispatching its
    // widened data, and report ServedBy::Runtime.
    let router = Router::new(Engine::Auto, Path::new("/nonexistent"), BackendKind::Shadow).unwrap();
    let metrics = Metrics::new();
    let data32: Vec<f32> = sample(21, 150).iter().map(|&x| x as f32).collect();
    let opts = QuantOptions { lambda1: 0.02, target_values: 8, ..Default::default() };
    let mut jobs = Vec::new();
    let mut rxs = Vec::new();
    for (i, method) in [QuantMethod::L1LeastSquare, QuantMethod::KMeans].iter().enumerate() {
        let (job, rx) =
            raw_job(i as u64 + 1, Payload::F32(data32.clone().into()), *method, opts.clone());
        jobs.push(job);
        rxs.push((method, rx));
    }
    let mut backend = ShadowBackend::new();
    serve_batch_runtime(&mut backend, &router, &metrics, jobs, 2);
    let wide: Vec<f64> = data32.iter().map(|&x| f64::from(x)).collect();
    let mut reference = ShadowBackend::new();
    for (method, rx) in rxs {
        let res = rx.recv().unwrap();
        assert_eq!(res.served_by, ServedBy::Runtime, "widened f32 still serves on the lane");
        let got = res.outcome.expect("widened job must succeed");
        let direct = router::dispatch_runtime(&mut reference, &wide, *method, &opts).unwrap();
        assert_eq!(got.codebook(), direct.codebook, "{method:?}: widening changed the result");
        assert_eq!(got.l2_loss().to_bits(), direct.l2_loss.to_bits());
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.served_runtime, 2);
    assert_eq!(snap.batches, 1);
}

#[test]
fn direct_serve_batch_runtime_fanout_is_bitwise_stable() {
    // The same drained batch through fanout 1 and fanout 4, directly at
    // the lane-logic level (no queues/timing involved): identical bits.
    let router = Router::new(Engine::Auto, Path::new("/nonexistent"), BackendKind::Shadow).unwrap();
    let mix = job_mix(16);
    let mut run = |fanout: usize| -> Vec<sqlsq::coordinator::job::JobOutput> {
        let metrics = Metrics::new();
        let mut jobs = Vec::new();
        let mut rxs = Vec::new();
        for (i, (data, method, opts)) in mix.iter().enumerate() {
            let payload = Payload::F64(data.clone().into());
            let (job, rx) = raw_job(i as u64 + 1, payload, *method, opts.clone());
            jobs.push(job);
            rxs.push(rx);
        }
        let mut backend = ShadowBackend::new();
        serve_batch_runtime(&mut backend, &router, &metrics, jobs, fanout);
        rxs.into_iter().map(|rx| rx.recv().unwrap().outcome.unwrap()).collect()
    };
    let serial = run(1);
    let fanned = run(4);
    for (a, b) in serial.iter().zip(&fanned) {
        assert_eq!(a.codebook(), b.codebook());
        assert_eq!(a.l2_loss().to_bits(), b.l2_loss().to_bits());
        assert_eq!(a.diag().iterations, b.diag().iterations);
    }
}
