//! Property: `BoundedQueue` under concurrent submit vs `pop_batch`.
//!
//! Sweeps a grid of (producers, items, capacity, max_batch, fill_wait)
//! shapes and asserts the batcher's contract:
//! * no job is lost or duplicated across concurrent producers/consumers;
//! * every drained batch has `1 ≤ len ≤ max_batch` — `pop_batch` never
//!   returns an empty batch while jobs are queued (or at all);
//! * after close, consumers drain exactly what remains.

use sqlsq::coordinator::queue::BoundedQueue;
use std::sync::Arc;
use std::time::Duration;

/// One concurrent scenario: `producers × items` pushes against
/// `consumers` batch-popping drains. Returns every (batch) drained.
fn run_scenario(
    producers: usize,
    items: usize,
    capacity: usize,
    max_batch: usize,
    fill_wait: Duration,
    consumers: usize,
) -> Vec<Vec<u64>> {
    let q = Arc::new(BoundedQueue::new(capacity));
    let producer_handles: Vec<_> = (0..producers)
        .map(|p| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..items {
                    assert!(q.push((p * 1_000_000 + i) as u64), "queue closed early");
                    if i % 7 == 0 {
                        std::thread::yield_now(); // jitter the interleaving
                    }
                }
            })
        })
        .collect();
    let consumer_handles: Vec<_> = (0..consumers)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut batches = Vec::new();
                while let Some(batch) =
                    q.pop_batch(max_batch, Duration::from_millis(50), fill_wait)
                {
                    assert!(!batch.is_empty(), "pop_batch returned an empty batch");
                    assert!(
                        batch.len() <= max_batch,
                        "batch of {} exceeds max_batch {max_batch}",
                        batch.len()
                    );
                    batches.push(batch);
                }
                batches
            })
        })
        .collect();
    for p in producer_handles {
        p.join().unwrap();
    }
    q.close();
    let mut all = Vec::new();
    for c in consumer_handles {
        all.extend(c.join().unwrap());
    }
    all
}

#[test]
fn no_item_lost_or_duplicated_across_shapes() {
    // (producers, items, capacity, max_batch, fill_wait_us, consumers)
    let grid = [
        (2usize, 300usize, 8usize, 4usize, 0u64, 1usize),
        (4, 250, 16, 5, 200, 2),
        (8, 125, 4, 3, 0, 2),
        (3, 200, 64, 32, 500, 1),
        (4, 150, 1, 1, 0, 3), // capacity 1: maximum contention
    ];
    for (producers, items, cap, max_batch, wait_us, consumers) in grid {
        let batches = run_scenario(
            producers,
            items,
            cap,
            max_batch,
            Duration::from_micros(wait_us),
            consumers,
        );
        let mut seen: Vec<u64> = batches.into_iter().flatten().collect();
        assert_eq!(
            seen.len(),
            producers * items,
            "count mismatch at shape p={producers} cap={cap} mb={max_batch}"
        );
        seen.sort_unstable();
        let before_dedup = seen.len();
        seen.dedup();
        assert_eq!(seen.len(), before_dedup, "duplicated items");
        // Exact multiset: every produced tag present once.
        let mut expect: Vec<u64> = (0..producers)
            .flat_map(|p| (0..items).map(move |i| (p * 1_000_000 + i) as u64))
            .collect();
        expect.sort_unstable();
        assert_eq!(seen, expect, "lost items at shape p={producers} cap={cap}");
    }
}

#[test]
fn fill_wait_lingers_but_never_serves_empty() {
    // A batch_wait window larger than the producer gap must never yield
    // an empty batch: phase 1 guarantees at least one queued item before
    // the linger, and the drain takes min(len, max).
    let q = Arc::new(BoundedQueue::new(32));
    q.push(1u64);
    // Nothing else arrives during the linger — still a 1-item batch.
    let b = q
        .pop_batch(8, Duration::from_millis(50), Duration::from_millis(20))
        .unwrap();
    assert_eq!(b, vec![1]);

    // Stragglers arriving inside the linger window join the batch.
    let q2 = Arc::clone(&q);
    let t = std::thread::spawn(move || {
        for i in 2..=4u64 {
            std::thread::sleep(Duration::from_millis(2));
            assert!(q2.push(i));
        }
    });
    let b2 = q
        .pop_batch(8, Duration::from_millis(200), Duration::from_millis(40))
        .unwrap();
    assert!(!b2.is_empty(), "lingering drain must carry ≥ 1 job");
    assert!(b2.len() <= 8);
    t.join().unwrap();
    // Whatever the linger missed is still queued, not lost — and every
    // follow-up drain is non-empty too.
    let mut all = b2;
    while all.len() < 3 {
        let b = q
            .pop_batch(8, Duration::from_millis(50), Duration::ZERO)
            .expect("queue is open and non-empty");
        assert!(!b.is_empty(), "pop_batch returned an empty batch");
        all.extend(b);
    }
    all.sort_unstable();
    assert_eq!(all, vec![2, 3, 4]);
}
