//! Weighted-objective differential suite (ISSUE-10 acceptance): the
//! importance-weighted solvers are pinned against brute-force references
//! of the weighted objective Σ wᵢ(xᵢ−qᵢ)²:
//!
//! * **DP optimality** — weighted `KMeansExact` matches an independent
//!   exhaustive search over contiguous partitions of the sorted distinct
//!   values, across seeds × both precision lanes;
//! * **weights help** — on the weighted objective, the weighted solve
//!   never loses to the unweighted solve, and strictly wins on a
//!   constructed skewed instance;
//! * **weighted refit fixed point** — every level of a weighted
//!   `L1LeastSquare` / `KMeansExact` solution equals the weighted mean
//!   of the elements assigned to it;
//! * **zero weights are free** — zero-weight elements never constrain
//!   the codebook;
//! * **entropy-constrained merge** — `entropy_budget` is respected for
//!   every budget, monotone in the budget, and a bitwise no-op when the
//!   budget already holds;
//! * **unsupported methods refuse** — `L0` / `TvExact` reject weights
//!   with `InvalidInput` instead of silently ignoring them.

use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{QuantMethod, QuantOptions, QuantRequest, Quantizer};
use sqlsq::Error;

fn weighted_loss(data: &[f64], w: &[f64], q: &[f64]) -> f64 {
    data.iter()
        .zip(q)
        .zip(w)
        .map(|((x, q), w)| w * (x - q) * (x - q))
        .sum()
}

/// Exhaustive reference for the optimal k-level weighted quantizer.
/// With non-negative weights the optimal 1-D clusters are contiguous on
/// the sorted distinct values, so the search enumerates every way to cut
/// them into at most `k` groups and prices each group at its weighted
/// mean. Deliberately naive — independent of the production DP.
fn brute_force_optimum(data: &[f64], w: &[f64], k: usize) -> f64 {
    let mut pts: Vec<(f64, f64)> = data.iter().copied().zip(w.iter().copied()).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut agg: Vec<(f64, f64)> = Vec::new();
    for (v, wi) in pts {
        match agg.last_mut() {
            Some(last) if last.0 == v => last.1 += wi,
            _ => agg.push((v, wi)),
        }
    }
    fn group_cost(g: &[(f64, f64)]) -> f64 {
        let tw: f64 = g.iter().map(|p| p.1).sum();
        if tw <= 0.0 {
            return 0.0;
        }
        let mu = g.iter().map(|p| p.0 * p.1).sum::<f64>() / tw;
        g.iter().map(|p| p.1 * (p.0 - mu) * (p.0 - mu)).sum()
    }
    fn best(agg: &[(f64, f64)], k: usize) -> f64 {
        if agg.len() <= k {
            return 0.0;
        }
        if k == 1 {
            return group_cost(agg);
        }
        let mut best_cost = f64::INFINITY;
        for cut in 1..agg.len() {
            let c = group_cost(&agg[..cut]) + best(&agg[cut..], k - 1);
            if c < best_cost {
                best_cost = c;
            }
        }
        best_cost
    }
    best(&agg, k.max(1))
}

/// A small weighted instance: `m` well-separated distinct values, some
/// duplicated, with positive weights (and one zero weight per instance).
fn small_instance(seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg32::seeded(seed);
    let m = 6 + (seed as usize % 4); // 6..=9 distinct values
    let mut values: Vec<f64> = (0..m).map(|j| j as f64 + rng.uniform(0.05, 0.45)).collect();
    // Duplicate a few values so multiplicity counts fold with weights.
    for _ in 0..3 {
        let pick = values[(rng.next_u32() as usize) % m];
        values.push(pick);
    }
    let weights: Vec<f64> = (0..values.len())
        .map(|i| if i == 2 { 0.0 } else { rng.uniform(0.1, 4.0) })
        .collect();
    (values, weights)
}

fn run_weighted(
    data: &[f64],
    weights: Option<&[f64]>,
    method: QuantMethod,
    opts: &QuantOptions,
) -> Vec<f64> {
    let mut req = QuantRequest::vector(data.to_vec()).method(method).options(opts.clone());
    if let Some(w) = weights {
        req = req.weights(w.to_vec());
    }
    Quantizer::new()
        .run(&req)
        .expect("weighted solve")
        .into_single()
        .expect("single item")
        .materialize_f64()
}

// ---------------------------------------------------------------------
// DP optimality vs brute force, both lanes
// ---------------------------------------------------------------------

#[test]
fn weighted_kmeans_exact_matches_the_brute_force_optimum_f64() {
    for seed in 0..6u64 {
        let (data, wts) = small_instance(seed);
        for k in [2usize, 3] {
            let opts = QuantOptions { target_values: k, ..Default::default() };
            let q = run_weighted(&data, Some(&wts), QuantMethod::KMeansExact, &opts);
            let got = weighted_loss(&data, &wts, &q);
            let want = brute_force_optimum(&data, &wts, k);
            assert!(
                (got - want).abs() <= 1e-8 * want.max(1.0),
                "seed {seed} k={k}: DP {got:.12e} vs brute force {want:.12e}"
            );
        }
    }
}

#[test]
fn weighted_kmeans_exact_matches_the_brute_force_optimum_f32_lane() {
    use sqlsq::quant::Precision;
    for seed in 0..4u64 {
        let (data, wts) = small_instance(100 + seed);
        // The f32 lane narrows the data first; the reference must see the
        // exact values the solver sees.
        let narrowed: Vec<f64> = data.iter().map(|&x| x as f32 as f64).collect();
        let opts = QuantOptions {
            target_values: 3,
            precision: Precision::F32,
            ..Default::default()
        };
        let mut req = QuantRequest::vector_f32(data.iter().map(|&x| x as f32).collect())
            .method(QuantMethod::KMeansExact)
            .options(opts);
        req = req.weights(wts.clone());
        let item = Quantizer::new().run(&req).unwrap().into_single().unwrap();
        let q = item.materialize_f64();
        let got = weighted_loss(&narrowed, &wts, &q);
        let want = brute_force_optimum(&narrowed, &wts, 3);
        // f32 arithmetic in the fold + DP: near-optimal, not bit-exact.
        assert!(
            (got - want).abs() <= 1e-3 * want.max(1e-6),
            "seed {seed}: f32 DP {got:.9e} vs brute force {want:.9e}"
        );
    }
}

// ---------------------------------------------------------------------
// Weights help on the weighted objective
// ---------------------------------------------------------------------

#[test]
fn weighted_solve_never_loses_to_unweighted_on_the_weighted_objective() {
    for seed in 0..6u64 {
        let (data, wts) = small_instance(200 + seed);
        let opts = QuantOptions { target_values: 2, ..Default::default() };
        let q_w = run_weighted(&data, Some(&wts), QuantMethod::KMeansExact, &opts);
        let q_u = run_weighted(&data, None, QuantMethod::KMeansExact, &opts);
        let lw = weighted_loss(&data, &wts, &q_w);
        let lu = weighted_loss(&data, &wts, &q_u);
        assert!(
            lw <= lu + 1e-10 * lu.max(1.0),
            "seed {seed}: weighted DP must not lose on its own objective \
             ({lw:.9e} vs {lu:.9e})"
        );
    }
}

#[test]
fn skewed_importance_strictly_beats_the_unweighted_codebook() {
    // Partition {0}, {0.55, 1.0} is optimal both ways, but the weighted
    // level of the second group sits at the weighted mean — upweighting
    // 0.55 by 10x drags it from 0.775 toward 0.55, a strict win.
    let data = vec![0.0, 0.55, 1.0];
    let wts = vec![1.0, 10.0, 1.0];
    let opts = QuantOptions { target_values: 2, ..Default::default() };
    let q_w = run_weighted(&data, Some(&wts), QuantMethod::KMeansExact, &opts);
    let q_u = run_weighted(&data, None, QuantMethod::KMeansExact, &opts);
    let lw = weighted_loss(&data, &wts, &q_w);
    let lu = weighted_loss(&data, &wts, &q_u);
    assert!(
        lw < lu * 0.9,
        "10x importance on the mid value must strictly improve the weighted \
         objective: weighted {lw:.6e} vs unweighted {lu:.6e}"
    );
}

// ---------------------------------------------------------------------
// Weighted refit fixed point: levels sit at weighted means
// ---------------------------------------------------------------------

#[test]
fn weighted_levels_are_the_weighted_means_of_their_elements() {
    for (method, opts) in [
        (QuantMethod::KMeansExact, QuantOptions { target_values: 3, ..Default::default() }),
        (
            QuantMethod::L1LeastSquare,
            QuantOptions { lambda1: 0.3, target_values: 64, ..Default::default() },
        ),
    ] {
        for seed in 0..4u64 {
            let (data, wts) = small_instance(300 + seed);
            let q = run_weighted(&data, Some(&wts), method, &opts);
            // Group elements by their assigned level.
            let mut groups: Vec<(f64, f64, f64)> = Vec::new(); // (level, Σwx, Σw)
            for ((x, qi), w) in data.iter().zip(&q).zip(&wts) {
                match groups.iter_mut().find(|g| g.0.to_bits() == qi.to_bits()) {
                    Some(g) => {
                        g.1 += w * x;
                        g.2 += w;
                    }
                    None => groups.push((*qi, w * x, *w)),
                }
            }
            for (level, swx, sw) in groups {
                if sw <= 0.0 {
                    continue; // zero-mass level: unconstrained
                }
                let mean = swx / sw;
                assert!(
                    (level - mean).abs() <= 1e-8 * mean.abs().max(1.0),
                    "{method:?} seed {seed}: level {level:.12} vs weighted mean {mean:.12}"
                );
            }
        }
    }
}

#[test]
fn zero_weight_elements_do_not_constrain_the_codebook() {
    // An enormous outlier with zero importance: the two levels serve the
    // weighted elements exactly, and the weighted loss is zero.
    let data = vec![0.0, 0.0, 1.0, 1.0, 100.0];
    let wts = vec![1.0, 1.0, 1.0, 1.0, 0.0];
    let opts = QuantOptions { target_values: 2, ..Default::default() };
    let q = run_weighted(&data, Some(&wts), QuantMethod::KMeansExact, &opts);
    assert!(
        weighted_loss(&data, &wts, &q) <= 1e-18,
        "zero-weight outlier must not displace the levels: {q:?}"
    );
}

// ---------------------------------------------------------------------
// Entropy-constrained merge through the facade
// ---------------------------------------------------------------------

/// Skewed data: 8 distinct values with very unequal multiplicities, so
/// the index entropy is well below log2(8) and merges have real choices.
fn skewed_data() -> Vec<f64> {
    let mut data = Vec::new();
    for (j, count) in [40usize, 20, 10, 8, 4, 2, 1, 1].iter().enumerate() {
        data.extend(std::iter::repeat(j as f64 * 0.7).take(*count));
    }
    data
}

fn run_with_budget(budget: Option<f64>) -> sqlsq::quant::Item {
    let mut req = QuantRequest::vector(skewed_data())
        .method(QuantMethod::KMeans)
        .options(QuantOptions { target_values: 8, seed: 4, ..Default::default() });
    if let Some(b) = budget {
        req = req.entropy_budget(b);
    }
    Quantizer::new().run(&req).unwrap().into_single().unwrap()
}

#[test]
fn entropy_budget_is_respected_for_every_budget() {
    for budget in [0.0, 0.5, 1.0, 1.5, 2.0] {
        let item = run_with_budget(Some(budget));
        let stats = item.compression(8);
        assert!(
            stats.index_entropy <= budget + 1e-9,
            "budget {budget}: entropy {:.6} over budget ({} levels)",
            stats.index_entropy,
            item.distinct_values()
        );
    }
    // Budget 0 forces a single level.
    assert_eq!(run_with_budget(Some(0.0)).distinct_values(), 1);
}

#[test]
fn entropy_merge_is_monotone_in_the_budget() {
    let budgets = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0];
    let mut prev_loss = f64::INFINITY;
    let mut prev_levels = 0usize;
    for &b in &budgets {
        let item = run_with_budget(Some(b));
        let loss = item.l2_loss();
        assert!(
            loss <= prev_loss + 1e-12,
            "budget {b}: loss {loss:.9e} must not exceed tighter-budget loss {prev_loss:.9e}"
        );
        assert!(
            item.distinct_values() >= prev_levels,
            "budget {b}: level count must not shrink as the budget loosens"
        );
        prev_loss = loss;
        prev_levels = item.distinct_values();
    }
}

#[test]
fn a_loose_budget_is_a_bitwise_no_op() {
    let plain = run_with_budget(None);
    let loose = run_with_budget(Some(64.0));
    let (a, b) = (plain.materialize_f64(), loose.materialize_f64());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits(), "loose budget must not touch the solution");
    }
    assert_eq!(plain.l2_loss().to_bits(), loose.l2_loss().to_bits());
}

// ---------------------------------------------------------------------
// Unsupported methods refuse weights
// ---------------------------------------------------------------------

#[test]
fn l0_and_tv_exact_reject_importance_weights() {
    let (data, wts) = small_instance(400);
    for method in [QuantMethod::L0, QuantMethod::TvExact] {
        let req = QuantRequest::vector(data.clone())
            .method(method)
            .options(QuantOptions { target_values: 3, ..Default::default() })
            .weights(wts.clone());
        // The rejection happens inside the solve, so it surfaces as the
        // (single) item's error, not as a request-level error.
        let err = Quantizer::new()
            .run(&req)
            .expect("request shape is valid")
            .into_single()
            .err()
            .unwrap_or_else(|| panic!("{method:?} must refuse weights"));
        match err {
            Error::InvalidInput(msg) => {
                assert!(msg.contains("weights"), "{method:?}: unexpected message {msg}")
            }
            other => panic!("{method:?}: wrong error kind {other:?}"),
        }
    }
}
