//! Integration: the coordinator's serve-path result cache (ISSUE-8).
//!
//! Properties pinned here, end to end through the public submit surface:
//!
//! * a cache hit is **bitwise-identical** to a cold solve, for every
//!   (method, lane) pair the coordinator serves, and is reported as
//!   [`ServedBy::Cache`] with the hit counted in metrics;
//! * N concurrent identical submits run **exactly one** engine solve
//!   (single-flight), all N receive identical bits;
//! * LRU eviction under a tiny byte budget never serves a stale entry —
//!   an evicted key re-solves and reproduces the original bits;
//! * with `CachePolicy::Off` every submit solves and no cache counters
//!   move.
//!
//! The λ-grid-extension warm-start property (a sweep extending a cached
//! grid resumes from the nearest solved point) lives at the quant layer:
//! see the `caching_facade_*` tests in `quant::api` — the coordinator
//! rejects sweep plans at admission.

use sqlsq::config::{CachePolicy, Config, Engine};
use sqlsq::coordinator::{Coordinator, ServedBy};
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{Precision, QuantMethod, QuantOptions};
use std::sync::Barrier;

fn sample(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.uniform(0.0, 1.0)).collect()
}

fn native_cfg(policy: CachePolicy, capacity: usize) -> Config {
    Config {
        workers: 2,
        queue_capacity: 128,
        max_batch: 8,
        batch_wait_us: 100,
        engine: Engine::Native,
        cache_policy: policy,
        cache_capacity_bytes: capacity,
        ..Default::default()
    }
}

#[test]
fn hit_is_bitwise_identical_to_cold_solve_across_methods_and_lanes() {
    let methods = [
        QuantMethod::L1LeastSquare,
        QuantMethod::KMeans,
        QuantMethod::ClusterLs,
        QuantMethod::L1,
    ];
    let c = Coordinator::start(native_cfg(CachePolicy::Lru, 1 << 20)).unwrap();
    let mut expected_hits = 0u64;
    for (mi, method) in methods.iter().enumerate() {
        let opts = QuantOptions {
            lambda1: 0.02,
            target_values: 8,
            seed: mi as u64,
            ..Default::default()
        };
        for lane in [Precision::F64, Precision::F32] {
            let data = sample(40 + mi as u64, 200);
            let (cold, hit) = match lane {
                Precision::F64 => (
                    c.quantize_blocking(data.clone(), *method, opts.clone()).unwrap(),
                    c.quantize_blocking(data.clone(), *method, opts.clone()).unwrap(),
                ),
                Precision::F32 => {
                    let d32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                    (
                        c.quantize_blocking_f32(d32.clone(), *method, opts.clone()).unwrap(),
                        c.quantize_blocking_f32(d32, *method, opts.clone()).unwrap(),
                    )
                }
            };
            expected_hits += 1;
            assert_eq!(cold.served_by, ServedBy::Native, "{method:?}/{lane:?}");
            assert_eq!(hit.served_by, ServedBy::Cache, "{method:?}/{lane:?} must hit");
            let (a, b) = (cold.outcome.unwrap(), hit.outcome.unwrap());
            assert_eq!(a.precision(), b.precision(), "{method:?}/{lane:?}: lane drift");
            assert_eq!(
                a.materialize(),
                b.materialize(),
                "{method:?}/{lane:?}: hit diverged from cold solve"
            );
            assert_eq!(a.l2_loss().to_bits(), b.l2_loss().to_bits(), "{method:?}/{lane:?}");
            assert_eq!(a.codebook(), b.codebook(), "{method:?}/{lane:?}");
            assert_eq!(a.diag().iterations, b.diag().iterations, "{method:?}/{lane:?}");
        }
    }
    let snap = c.shutdown();
    assert_eq!(snap.cache_hits, expected_hits);
    assert_eq!(snap.cache_misses, expected_hits, "each pair: one miss, one hit");
    assert!((snap.cache_hit_rate - 0.5).abs() < 1e-12);
    assert!(snap.cache_bytes_saved > 0);
    assert_eq!(
        snap.stage_samples, expected_hits,
        "every pair ran exactly one engine solve"
    );
}

#[test]
fn concurrent_identical_submits_run_exactly_one_solve() {
    const N: usize = 8;
    let c = Coordinator::start(native_cfg(CachePolicy::Lru, 1 << 20)).unwrap();
    let data = sample(7, 500);
    let opts = QuantOptions { lambda1: 0.01, target_values: 8, ..Default::default() };
    let barrier = Barrier::new(N);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let (c, data, opts, barrier) = (&c, &data, &opts, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    c.quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone()).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let outs: Vec<_> = results
        .into_iter()
        .map(|r| r.outcome.expect("every duplicate must succeed"))
        .collect();
    let reference = outs[0].materialize();
    for out in &outs {
        assert_eq!(out.materialize(), reference, "duplicates must receive identical bits");
        assert_eq!(out.l2_loss().to_bits(), outs[0].l2_loss().to_bits());
    }
    let snap = c.shutdown();
    assert_eq!(snap.stage_samples, 1, "exactly one engine solve across {N} duplicates");
    assert_eq!(snap.cache_hits, N as u64 - 1, "everyone but the leader is a hit");
    assert_eq!(snap.cache_misses, 1);
    assert_eq!(snap.completed, N as u64);
    assert_eq!(snap.failed, 0);
}

#[test]
fn eviction_under_tiny_budget_re_solves_and_never_serves_stale() {
    // A budget far below one compact result: every insert evicts its
    // predecessor, so alternating keys miss every time — and each
    // re-solve must reproduce the original bits (nothing stale, nothing
    // corrupted by churn).
    let c = Coordinator::start(native_cfg(CachePolicy::Lru, 64)).unwrap();
    let opts = QuantOptions { target_values: 4, ..Default::default() };
    let a = sample(100, 300);
    let b = sample(101, 300);
    let first_a = c
        .quantize_blocking(a.clone(), QuantMethod::KMeans, opts.clone())
        .unwrap()
        .outcome
        .unwrap();
    let first_b = c
        .quantize_blocking(b.clone(), QuantMethod::KMeans, opts.clone())
        .unwrap()
        .outcome
        .unwrap();
    for _ in 0..3 {
        let ra = c.quantize_blocking(a.clone(), QuantMethod::KMeans, opts.clone()).unwrap();
        let rb = c.quantize_blocking(b.clone(), QuantMethod::KMeans, opts.clone()).unwrap();
        let (oa, ob) = (ra.outcome.unwrap(), rb.outcome.unwrap());
        assert_eq!(oa.materialize(), first_a.materialize(), "churn changed a's result");
        assert_eq!(ob.materialize(), first_b.materialize(), "churn changed b's result");
        assert_eq!(oa.l2_loss().to_bits(), first_a.l2_loss().to_bits());
        assert_eq!(ob.l2_loss().to_bits(), first_b.l2_loss().to_bits());
    }
    let snap = c.shutdown();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 8);
    // With a's and b's entries evicting each other, re-solves dominate:
    // the cache must not have answered more often than physically
    // possible (at most one survivor between any two submits).
    assert!(
        snap.cache_misses >= 7,
        "a 64-byte budget cannot retain both keys (misses: {})",
        snap.cache_misses
    );
}

#[test]
fn cache_off_control_solves_every_submit() {
    let c = Coordinator::start(native_cfg(CachePolicy::Off, 1 << 20)).unwrap();
    let data = sample(9, 200);
    let opts = QuantOptions { target_values: 8, ..Default::default() };
    let first = c.quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone()).unwrap();
    let second = c.quantize_blocking(data.clone(), QuantMethod::KMeans, opts.clone()).unwrap();
    assert_eq!(first.served_by, ServedBy::Native);
    assert_eq!(second.served_by, ServedBy::Native, "cache off: no hits");
    assert_eq!(
        first.outcome.unwrap().materialize(),
        second.outcome.unwrap().materialize(),
        "determinism holds with the cache off"
    );
    let snap = c.shutdown();
    assert_eq!(snap.stage_samples, 2, "both submits solved");
    assert_eq!(snap.cache_hits, 0);
    assert_eq!(snap.cache_misses, 0);
}
