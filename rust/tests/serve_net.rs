//! Network serve front end, end to end over real loopback sockets
//! (ISSUE-9 acceptance):
//!
//! * **loopback identity** — a quantization served over the wire is
//!   bitwise-identical (level bits, indices, loss bits) to the same
//!   request submitted to an in-process coordinator, on both codecs ×
//!   both precision lanes;
//! * **wire robustness** — malformed, truncated and oversized frames
//!   never panic the server: protocol violations close one connection,
//!   bad payloads in valid frames get an error reply and the
//!   connection survives;
//! * **saturation** — a tiny queue under flood sheds with retry-after
//!   hints instead of hanging, and the graceful drain completes every
//!   accepted job;
//! * **fairness** — a flooding tenant exhausts only its own token
//!   bucket; a polite tenant's requests all complete;
//! * **tenant cache partitioning** — with `cache_shared false`, one
//!   tenant's cached result is invisible to another over the wire;
//! * **weighted requests** (ISSUE-10) — per-element importance weights
//!   round-trip bitwise on both codecs, malformed weights get an error
//!   reply without killing the connection, and weighted results cache
//!   under their own fingerprint (uniform weights alias unweighted).

use sqlsq::config::{Config, Engine};
use sqlsq::coordinator::{Coordinator, Payload};
use sqlsq::data::rng::Pcg32;
use sqlsq::quant::{Precision, QuantMethod, QuantOptions, QuantRequest};
use sqlsq::serve::{
    read_frame, write_frame, Client, Codec, Frame, FrameKind, ReadOutcome, ServeConfig,
    Server, WireReply, WireRequest,
};
use std::io::Write as _;
use std::net::TcpStream;
use std::time::Duration;

fn native_config() -> Config {
    Config { workers: 2, engine: Engine::parse("native").unwrap(), ..Config::default() }
}

fn start_server(cfg: Config, scfg: ServeConfig) -> Server {
    let coord = Coordinator::start(cfg).expect("coordinator");
    Server::start(coord, ServeConfig { addr: "127.0.0.1:0".into(), ..scfg }).expect("server")
}

fn clustered(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|i| {
            let center = [0.1, 0.35, 0.6, 0.9][i % 4];
            ((center + rng.uniform(-0.02, 0.02)) * 200.0).round() / 200.0
        })
        .collect()
}

// ---------------------------------------------------------------------
// 1. Loopback bitwise identity, both codecs × both lanes
// ---------------------------------------------------------------------

#[test]
fn loopback_results_are_bitwise_identical_to_in_process_on_both_codecs_and_lanes() {
    let baseline = Coordinator::start(native_config()).unwrap();
    let server = start_server(native_config(), ServeConfig::default());
    let addr = server.addr();

    let data = clustered(96, 11);
    let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    for method in [QuantMethod::L1LeastSquare, QuantMethod::KMeans] {
        for lane in [Precision::F64, Precision::F32] {
            let opts = QuantOptions {
                lambda1: 0.03,
                target_values: 4,
                kmeans_restarts: 2,
                seed: 5,
                precision: lane,
                ..Default::default()
            };

            // In-process reference result.
            let req = match lane {
                Precision::F64 => QuantRequest::vector(data.clone()),
                Precision::F32 => QuantRequest::vector_f32(data32.clone()),
            }
            .method(method)
            .options(opts.clone());
            let (_, rx) = baseline.submit_request(req).unwrap();
            let out = rx.recv().unwrap().outcome.expect("baseline solve");
            let cb = out.codebook();

            for codec in [Codec::Json, Codec::Binary] {
                let mut client = Client::connect(addr, codec, Some("ident")).unwrap();
                let wire_req = WireRequest {
                    method,
                    opts: opts.clone(),
                    payload: match lane {
                        Precision::F64 => Payload::F64(data.clone().into()),
                        Precision::F32 => Payload::F32(data32.clone().into()),
                    },
                    weights: None,
                };
                let tag = format!("{method:?}/{lane:?}/{codec:?}");
                let WireReply::Result(r) = client.quant(&wire_req).unwrap() else {
                    panic!("{tag}: expected a result");
                };
                assert_eq!(r.lane, lane, "{tag}");
                assert_eq!(r.levels.len(), cb.levels.len(), "{tag}: level count");
                for (a, b) in r.levels.iter().zip(&cb.levels) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{tag}: level bits");
                }
                assert_eq!(r.indices, cb.indices, "{tag}: indices");
                assert_eq!(
                    r.l2_loss.to_bits(),
                    out.l2_loss().to_bits(),
                    "{tag}: loss bits"
                );
            }
        }
    }
    server.shutdown();
    baseline.shutdown();
}

// ---------------------------------------------------------------------
// 2. Wire robustness: malformed / truncated / oversized frames
// ---------------------------------------------------------------------

fn raw_conn(server: &Server) -> TcpStream {
    let s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn assert_server_alive(server: &Server) {
    let mut c = Client::connect(server.addr(), Codec::Binary, None).unwrap();
    c.ping().expect("server must survive");
}

#[test]
fn malformed_frames_close_one_connection_without_killing_the_server() {
    let server = start_server(native_config(), ServeConfig::default());

    // Garbage bytes: bad magic is a protocol violation — the server
    // sends one error frame and hangs up.
    let mut s = raw_conn(&server);
    s.write_all(b"garbage-bytes-no-magic-here!").unwrap();
    match read_frame(&mut s).unwrap() {
        ReadOutcome::Frame(f) => assert_eq!(f.kind, FrameKind::Error),
        other => panic!("expected an error frame, got {other:?}"),
    }
    assert!(
        matches!(read_frame(&mut s), Ok(ReadOutcome::Eof) | Err(_)),
        "connection must be closed after a protocol violation"
    );
    assert_server_alive(&server);

    // Oversized payload claim: rejected before allocation, same path.
    let mut s = raw_conn(&server);
    let mut header = Vec::new();
    header.extend_from_slice(b"sqlq");
    header.push(1); // version
    header.push(0x01); // Quant
    header.push(0); // json
    header.push(0); // no tenant
    header.extend_from_slice(&(64u32 << 20).to_le_bytes()); // 64 MiB claim
    s.write_all(&header).unwrap();
    match read_frame(&mut s).unwrap() {
        ReadOutcome::Frame(f) => assert_eq!(f.kind, FrameKind::Error),
        other => panic!("expected an error frame, got {other:?}"),
    }
    assert_server_alive(&server);

    // Truncated frame: a valid header whose body never arrives. The
    // server times the stall out and drops the connection silently.
    let mut s = raw_conn(&server);
    let mut header = Vec::new();
    header.extend_from_slice(b"sqlq");
    header.push(1);
    header.push(0x01);
    header.push(0);
    header.push(0);
    header.extend_from_slice(&100u32.to_le_bytes());
    s.write_all(&header).unwrap();
    s.write_all(&[0u8; 10]).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    assert!(
        matches!(read_frame(&mut s), Ok(ReadOutcome::Eof) | Err(_)),
        "truncated frame must close the connection, not hang"
    );
    assert_server_alive(&server);

    server.shutdown();
}

#[test]
fn bad_payload_in_a_valid_frame_errors_but_the_connection_survives() {
    let server = start_server(native_config(), ServeConfig::default());
    let mut s = raw_conn(&server);

    let f = Frame::new(FrameKind::Quant, Codec::Json, b"this is not json".to_vec());
    write_frame(&mut s, &f).unwrap();
    match read_frame(&mut s).unwrap() {
        ReadOutcome::Frame(f) => assert_eq!(f.kind, FrameKind::Error),
        other => panic!("expected an error frame, got {other:?}"),
    }

    // Same connection still serves: ping/pong round-trips.
    let ping = Frame::new(FrameKind::Ping, Codec::Json, Vec::new());
    write_frame(&mut s, &ping).unwrap();
    match read_frame(&mut s).unwrap() {
        ReadOutcome::Frame(f) => assert_eq!(f.kind, FrameKind::Pong),
        other => panic!("expected a pong, got {other:?}"),
    }

    server.shutdown();
}

// ---------------------------------------------------------------------
// 3. Saturation: tiny queue + flood → SHED, drain loses nothing
// ---------------------------------------------------------------------

#[test]
fn tiny_queue_flood_sheds_with_hints_and_drain_completes_every_accepted_job() {
    let cfg = Config { workers: 1, queue_capacity: 1, ..native_config() };
    let server = start_server(cfg, ServeConfig { shed_retry_ms: 40, ..Default::default() });
    let addr = server.addr();

    let flood = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            handles.push(scope.spawn(move || {
                let mut client = Client::connect(addr, Codec::Binary, None).unwrap();
                let mut completed = 0u64;
                let mut shed = 0u64;
                for i in 0..12u64 {
                    // Distinct payloads: the cache can't absorb the flood.
                    let data = clustered(512, 1000 + t * 100 + i);
                    let req = WireRequest {
                        method: QuantMethod::IterativeL1,
                        opts: QuantOptions { target_values: 6, ..Default::default() },
                        payload: Payload::F64(data.into()),
                        weights: None,
                    };
                    match client.quant(&req).expect("transport must stay up") {
                        WireReply::Result(_) => completed += 1,
                        WireReply::Shed { retry_after_ms, .. } => {
                            assert!(retry_after_ms > 0, "shed must carry a hint");
                            shed += 1;
                        }
                        WireReply::Error(e) => panic!("unexpected error: {e}"),
                    }
                }
                (completed, shed)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0u64, 0u64), |acc, r| (acc.0 + r.0, acc.1 + r.1))
    });

    let (completed, shed) = flood;
    assert_eq!(completed + shed, 48, "every request got an explicit answer");
    assert!(shed > 0, "a 1-deep queue under 4-way flood must shed");
    assert!(completed > 0, "the queue still makes progress under flood");

    let snap = server.shutdown();
    assert_eq!(
        snap.completed + snap.failed,
        snap.submitted,
        "drain must finish every accepted job: {}",
        snap.summary()
    );
    assert_eq!(snap.completed, completed, "wire results match coordinator completions");
}

// ---------------------------------------------------------------------
// 4. Fairness: a flooder cannot starve a polite tenant
// ---------------------------------------------------------------------

#[test]
fn flooding_tenant_exhausts_only_its_own_bucket() {
    // Slow refill, burst 4: the flooder's 24 rapid-fire requests mostly
    // shed; the polite tenant's 3 (under its own burst) all complete.
    let server = start_server(
        native_config(),
        ServeConfig { tenant_rate: 0.1, tenant_burst: 4.0, ..Default::default() },
    );
    let addr = server.addr();

    let flooder = std::thread::spawn(move || {
        let mut client = Client::connect(addr, Codec::Binary, Some("flooder")).unwrap();
        let mut completed = 0u64;
        let mut shed = 0u64;
        for i in 0..24u64 {
            let req = WireRequest {
                method: QuantMethod::KMeans,
                opts: QuantOptions {
                    target_values: 4,
                    kmeans_restarts: 1,
                    ..Default::default()
                },
                payload: Payload::F64(clustered(64, 50 + i).into()),
                weights: None,
            };
            match client.quant(&req).unwrap() {
                WireReply::Result(_) => completed += 1,
                WireReply::Shed { .. } => shed += 1,
                WireReply::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        (completed, shed)
    });

    let mut polite = Client::connect(addr, Codec::Binary, Some("polite")).unwrap();
    let mut polite_done = 0u64;
    for i in 0..3u64 {
        let req = WireRequest {
            method: QuantMethod::KMeans,
            opts: QuantOptions { target_values: 4, kmeans_restarts: 1, ..Default::default() },
            payload: Payload::F64(clustered(64, 900 + i).into()),
            weights: None,
        };
        match polite.quant(&req).unwrap() {
            WireReply::Result(_) => polite_done += 1,
            other => panic!("polite tenant must never be shed: {other:?}"),
        }
    }
    let (flooder_done, flooder_shed) = flooder.join().unwrap();

    assert_eq!(polite_done, 3, "polite tenant completes everything");
    assert!(flooder_shed > 0, "flooder runs out of tokens");
    assert!(
        flooder_done <= 6,
        "flooder is capped near its burst, got {flooder_done} completions"
    );
    server.shutdown();
}

// ---------------------------------------------------------------------
// 5. Tenant cache partitioning over the wire
// ---------------------------------------------------------------------

#[test]
fn partitioned_cache_keeps_tenants_results_invisible_to_each_other_over_the_wire() {
    let cfg = Config { cache_shared: false, ..native_config() };
    let server = start_server(cfg, ServeConfig::default());
    let addr = server.addr();

    let req = WireRequest {
        method: QuantMethod::KMeans,
        opts: QuantOptions { target_values: 4, kmeans_restarts: 1, ..Default::default() },
        payload: Payload::F64(clustered(64, 3).into()),
        weights: None,
    };
    let mut client = Client::connect(addr, Codec::Binary, None).unwrap();

    let serve = |c: &mut Client, tenant: &str, req: &WireRequest| -> String {
        match c.quant_as(Some(tenant), req).unwrap() {
            WireReply::Result(r) => r.served_by,
            other => panic!("expected result, got {other:?}"),
        }
    };

    assert_eq!(serve(&mut client, "alice", &req), "native", "alice's first solve");
    assert_eq!(
        serve(&mut client, "bob", &req),
        "native",
        "identical payload, different tenant: partitioned cache must re-solve"
    );
    assert_eq!(serve(&mut client, "alice", &req), "cache", "alice's resubmit hits");
    server.shutdown();
}

// ---------------------------------------------------------------------
// 6. Weighted requests over the wire (ISSUE-10)
// ---------------------------------------------------------------------

fn importance(n: usize) -> Vec<f64> {
    (0..n).map(|i| 0.25 + (i % 9) as f64 * 0.5).collect()
}

#[test]
fn weighted_requests_round_trip_bitwise_on_both_codecs_and_lanes() {
    let baseline = Coordinator::start(native_config()).unwrap();
    let server = start_server(native_config(), ServeConfig::default());
    let addr = server.addr();

    let data = clustered(96, 21);
    let data32: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    let wts = importance(data.len());
    for lane in [Precision::F64, Precision::F32] {
        let opts = QuantOptions {
            target_values: 4,
            kmeans_restarts: 2,
            seed: 7,
            precision: lane,
            ..Default::default()
        };

        // In-process weighted reference result.
        let req = match lane {
            Precision::F64 => QuantRequest::vector(data.clone()),
            Precision::F32 => QuantRequest::vector_f32(data32.clone()),
        }
        .method(QuantMethod::KMeans)
        .options(opts.clone())
        .weights(wts.clone());
        let (_, rx) = baseline.submit_request(req).unwrap();
        let out = rx.recv().unwrap().outcome.expect("baseline weighted solve");
        let cb = out.codebook();

        for codec in [Codec::Json, Codec::Binary] {
            let mut client = Client::connect(addr, codec, Some("wident")).unwrap();
            let wire_req = WireRequest {
                method: QuantMethod::KMeans,
                opts: opts.clone(),
                payload: match lane {
                    Precision::F64 => Payload::F64(data.clone().into()),
                    Precision::F32 => Payload::F32(data32.clone().into()),
                },
                weights: Some(wts.clone()),
            };
            let tag = format!("weighted/{lane:?}/{codec:?}");
            let WireReply::Result(r) = client.quant(&wire_req).unwrap() else {
                panic!("{tag}: expected a result");
            };
            assert_eq!(r.lane, lane, "{tag}");
            assert_eq!(r.levels.len(), cb.levels.len(), "{tag}: level count");
            for (a, b) in r.levels.iter().zip(&cb.levels) {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: level bits");
            }
            assert_eq!(r.indices, cb.indices, "{tag}: indices");
            assert_eq!(r.l2_loss.to_bits(), out.l2_loss().to_bits(), "{tag}: loss bits");
        }
    }
    server.shutdown();
    baseline.shutdown();
}

#[test]
fn malformed_weights_error_over_the_wire_and_the_connection_survives() {
    let server = start_server(native_config(), ServeConfig::default());
    let mut client = Client::connect(server.addr(), Codec::Binary, None).unwrap();

    let data = clustered(64, 31);
    let opts = QuantOptions { target_values: 4, kmeans_restarts: 1, ..Default::default() };
    let mk = |weights: Option<Vec<f64>>| WireRequest {
        method: QuantMethod::KMeans,
        opts: opts.clone(),
        payload: Payload::F64(data.clone().into()),
        weights,
    };

    // JSON codec can express a length mismatch (binary pins the count
    // to the payload length, making it unrepresentable on the wire).
    let mut jclient = Client::connect(server.addr(), Codec::Json, None).unwrap();
    let short = mk(Some(vec![1.0; data.len() - 1]));
    match jclient.quant(&short).unwrap() {
        WireReply::Error(e) => assert!(e.contains("weights"), "unexpected message: {e}"),
        other => panic!("length-mismatched weights must error, got {other:?}"),
    }

    // NaN, negative, and all-zero weights are admission errors on any
    // codec: an error frame, not a dropped connection.
    for bad in [
        {
            let mut w = vec![1.0; data.len()];
            w[5] = f64::NAN;
            w
        },
        {
            let mut w = vec![1.0; data.len()];
            w[0] = -2.0;
            w
        },
        vec![0.0; data.len()],
    ] {
        match client.quant(&mk(Some(bad))).unwrap() {
            WireReply::Error(e) => assert!(e.contains("weights"), "unexpected message: {e}"),
            other => panic!("malformed weights must error, got {other:?}"),
        }
    }

    // Both connections still serve a valid request afterwards.
    for c in [&mut client, &mut jclient] {
        match c.quant(&mk(None)).unwrap() {
            WireReply::Result(_) => {}
            other => panic!("connection must survive malformed weights: {other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn weighted_results_cache_under_their_own_fingerprint_over_the_wire() {
    let server = start_server(native_config(), ServeConfig::default());
    let mut client = Client::connect(server.addr(), Codec::Binary, None).unwrap();

    let data = clustered(64, 41);
    let opts = QuantOptions { target_values: 4, kmeans_restarts: 1, ..Default::default() };
    let mk = |weights: Option<Vec<f64>>| WireRequest {
        method: QuantMethod::KMeans,
        opts: opts.clone(),
        payload: Payload::F64(data.clone().into()),
        weights,
    };
    let serve = |c: &mut Client, req: &WireRequest| -> String {
        match c.quant(req).unwrap() {
            WireReply::Result(r) => r.served_by,
            other => panic!("expected result, got {other:?}"),
        }
    };

    assert_eq!(serve(&mut client, &mk(None)), "native", "unweighted cold solve");
    assert_eq!(
        serve(&mut client, &mk(Some(importance(data.len())))),
        "native",
        "same payload with weights is a different job: cache must miss"
    );
    assert_eq!(
        serve(&mut client, &mk(Some(importance(data.len())))),
        "cache",
        "identical weighted resubmit hits"
    );
    assert_eq!(serve(&mut client, &mk(None)), "cache", "unweighted entry is untouched");
    assert_eq!(
        serve(&mut client, &mk(Some(vec![3.0; data.len()]))),
        "cache",
        "uniform weights alias the unweighted cache entry"
    );
    server.shutdown();
}
