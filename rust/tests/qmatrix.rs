//! Property suite for the quantized-compute subsystem (PR 7): `QMatrix`
//! matvec raced against decode-then-dense (bitwise on the f64 lane,
//! tolerance-gated on f32), residual-cascade error monotonicity, the
//! stacked compression accounting, the wire round trip, and the
//! empty/1-level/k=1 edges — all through the public API.

use sqlsq::jsonio;
use sqlsq::linalg::matrix::Matrix;
use sqlsq::quant::tensor::Grouping;
use sqlsq::quant::{QMatrix, QuantMethod, QuantOptions, QuantRequest, Quantizer};

/// Deterministic clustered weights (the NN-weights shape the paper
/// quantizes) without an RNG dependency in the test.
fn weights(rows: usize, cols: usize, seed: u64) -> Matrix {
    Matrix::from_fn(rows, cols, |i, j| {
        let t = (i * cols + j) as f64 + seed as f64 * 0.37;
        let c = [-0.7, -0.25, 0.05, 0.4, 0.85][((i * 7 + j * 3 + seed as usize) % 5)];
        c + (t * 0.9311).sin() * 0.02
    })
}

fn probe(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i as f64) * 0.531).cos() * 1.5).collect()
}

fn opts() -> QuantOptions {
    QuantOptions { kmeans_restarts: 2, ..QuantOptions::default() }
}

const GROUPINGS: [Grouping; 3] =
    [Grouping::PerTensor, Grouping::PerRow, Grouping::PerColumn];

#[test]
fn single_level_matvec_is_bitwise_decode_then_dense_all_groupings() {
    for (rows, cols) in [(1usize, 1usize), (7, 13), (33, 8), (64, 5)] {
        let m = weights(rows, cols, (rows + cols) as u64);
        let x = probe(rows);
        for grouping in GROUPINGS {
            for bits in [1u32, 2, 4] {
                let qm = QMatrix::quantize(&m, grouping, QuantMethod::KMeans, &opts(), bits)
                    .unwrap();
                let dense = qm.decode();
                let want =
                    Matrix::from_vec(1, rows, x.clone()).unwrap().matmul(&dense).unwrap();
                let got = qm.matvec(&x);
                for (a, b) in got.iter().zip(want.row(0)) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{rows}x{cols} {grouping:?} {bits}-bit"
                    );
                }
            }
        }
    }
}

#[test]
fn f32_lane_matvec_tracks_decode_then_dense_within_tolerance() {
    let m = weights(48, 17, 5);
    let qm = QMatrix::residual_levels(
        &m,
        Grouping::PerColumn,
        QuantMethod::KMeans,
        &opts(),
        &[3, 2],
        0.0,
    )
    .unwrap();
    let q32 = qm.to_f32();
    let x32: Vec<f32> = probe(48).iter().map(|&v| v as f32).collect();
    // f32 reference: decode the f32 planes densely, then a naive matvec.
    let flat = q32.decode_flat();
    let mut want = vec![0.0f32; 17];
    for (i, &xi) in x32.iter().enumerate() {
        for (wj, &f) in want.iter_mut().zip(&flat[i * 17..(i + 1) * 17]) {
            *wj += xi * f;
        }
    }
    for (a, b) in q32.matvec(&x32).iter().zip(&want) {
        let scale = b.abs().max(1.0);
        assert!((a - b).abs() <= 1e-3 * scale, "f32 lane diverged: {a} vs {b}");
    }
}

#[test]
fn cascade_error_monotone_and_levels_stack_bits() {
    let m = weights(40, 12, 9);
    for grouping in GROUPINGS {
        let (qm, trace) = QMatrix::residual_levels_traced(
            &m,
            grouping,
            QuantMethod::KMeans,
            &opts(),
            &[1, 2, 2],
            0.0,
        )
        .unwrap();
        assert_eq!(trace.len(), 3, "{grouping:?}: norm_tol 0 runs every level");
        let mut prev = f64::INFINITY;
        for lv in &trace {
            assert!(lv.rel_error <= prev + 1e-12, "{grouping:?}: error must not grow");
            prev = lv.rel_error;
        }
        assert_eq!(trace.last().unwrap().cum_bits, 5);
        let s = qm.stats();
        assert_eq!(s.n, 40 * 12, "stacking covers the same elements once");
        assert_eq!(s.bits_per_idx_packed, 5, "cascade planes add packed bits");
        assert!(s.compact_bytes < s.dense_bytes);
    }
}

#[test]
fn cascade_through_the_request_front_door_matches_qmatrix_accounting() {
    // The same cascade driven through Quantizer::run's Plan::Cascade on a
    // single vector: per-level items whose stacked stats agree with the
    // QMatrix (PerTensor over a 1-row matrix is the same flat problem).
    let m = weights(1, 96, 3);
    let req = QuantRequest::matrix(m.clone(), Grouping::PerTensor)
        .method(QuantMethod::KMeans)
        .options(opts())
        .residual_levels(vec![2, 2], 0.0);
    let resp = Quantizer::new().run(&req).unwrap();
    let stacked = resp.compression_cascade().unwrap();
    let qm = QMatrix::residual_levels(
        &m,
        Grouping::PerTensor,
        QuantMethod::KMeans,
        &opts(),
        &[2, 2],
        0.0,
    )
    .unwrap();
    let s = qm.stats();
    assert_eq!(stacked.n, s.n);
    assert_eq!(stacked.bits_per_idx_packed, s.bits_per_idx_packed);
    assert_eq!(stacked.dense_bytes, s.dense_bytes);
}

#[test]
fn norm_tol_prunes_exactly_representable_groups() {
    // Two distinct values per column: a 1-bit plane is exact, so the
    // cascade must stop after one level under any positive tolerance.
    let m = Matrix::from_fn(12, 3, |i, j| if (i + j) % 2 == 0 { 0.25 } else { 0.75 });
    let qm = QMatrix::residual_levels(
        &m,
        Grouping::PerColumn,
        QuantMethod::KMeans,
        &opts(),
        &[1, 1, 1, 1],
        1e-9,
    )
    .unwrap();
    assert_eq!(qm.num_levels(), 1);
    assert!(qm.approx_error(&m) <= 1e-12);
}

#[test]
fn k1_single_level_and_empty_edges() {
    // k = 1: a constant matrix collapses to one level; matvec is the
    // row-sum scaled by it.
    let m = Matrix::from_fn(5, 4, |_, _| -0.5);
    let qm = QMatrix::quantize(&m, Grouping::PerRow, QuantMethod::KMeans, &opts(), 1).unwrap();
    let y = qm.matvec(&[1.0; 5]);
    for v in &y {
        assert!((v + 2.5).abs() < 1e-9);
    }
    // Empty matrices are rejected at every door.
    assert!(QMatrix::from_parts(0, 3, Grouping::PerRow, vec![]).is_err());
    assert!(QMatrix::from_parts(3, 0, Grouping::PerRow, vec![]).is_err());
    // Empty bit list / zero-width levels are rejected.
    assert!(QMatrix::residual_levels(
        &m,
        Grouping::PerRow,
        QuantMethod::KMeans,
        &opts(),
        &[],
        0.0
    )
    .is_err());
    assert!(QMatrix::residual_levels(
        &m,
        Grouping::PerRow,
        QuantMethod::KMeans,
        &opts(),
        &[0],
        0.0
    )
    .is_err());
}

#[test]
fn wire_roundtrip_preserves_matvec_bitwise() {
    let m = weights(21, 6, 13);
    for grouping in GROUPINGS {
        let qm = QMatrix::residual_levels(
            &m,
            grouping,
            QuantMethod::KMeans,
            &opts(),
            &[2, 1],
            0.0,
        )
        .unwrap();
        let wire = jsonio::qmatrix_to_json(&qm, vec![]).to_pretty();
        let back = jsonio::qmatrix_from_json(&jsonio::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, qm, "{grouping:?}");
        let x = probe(21);
        for (a, b) in back.matvec(&x).iter().zip(qm.matvec(&x)) {
            assert_eq!(a.to_bits(), b.to_bits(), "{grouping:?}");
        }
    }
}

#[test]
fn gemv_composes_with_matvec() {
    let m = weights(10, 4, 1);
    let qm =
        QMatrix::quantize(&m, Grouping::PerColumn, QuantMethod::KMeans, &opts(), 3).unwrap();
    let x = probe(10);
    let base = qm.matvec(&x);
    let mut y = vec![2.0f64; 4];
    qm.gemv(0.5, &x, -1.0, &mut y);
    for (yi, bi) in y.iter().zip(&base) {
        assert_eq!(yi.to_bits(), (0.5 * bi - 2.0).to_bits());
    }
}
