//! Property suite for the `linalg::kernels` layer: every kernel against a
//! naive scalar reference, **bitwise** on the f64 lane (the repository's
//! reference precision — kernels must reproduce the exact legacy
//! accumulation order) and tolerance-gated on the f32 lane (whose
//! reductions may reassociate across independent accumulators), across
//! odd lengths, chunk boundaries, and empty inputs; plus pack→unpack
//! round trips for the ⌈log₂ k⌉-bit index planes at the k values the
//! bit-width formula steps on.

use sqlsq::linalg::kernels;
use sqlsq::quant::{Codebook, PackedIndices};

/// Deterministic pseudo-random data without pulling in an RNG: a sine
/// scramble covering sign changes, magnitudes around 1, and exact zeros.
fn data64(n: usize, seed: u64) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let x = ((i as f64 + seed as f64 * 0.611) * 0.7311).sin() * 2.5;
            if i % 17 == 3 {
                0.0
            } else {
                x
            }
        })
        .collect()
}

fn data32(n: usize, seed: u64) -> Vec<f32> {
    data64(n, seed).iter().map(|&x| x as f32).collect()
}

/// Lengths hitting empty, the strict-unroll chunk (8) and f32 lane count
/// (4) boundaries ±1, and a few odd sizes past them.
const LENGTHS: &[usize] =
    &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 257];

#[test]
fn sum_f64_bitwise_matches_sequential_reference() {
    for &n in LENGTHS {
        let a = data64(n, 1);
        let mut want = 0.0f64;
        for &x in &a {
            want += x;
        }
        assert_eq!(kernels::sum(&a).to_bits(), want.to_bits(), "n={n}");
    }
}

#[test]
fn sum_f32_within_tolerance_of_f64_reference() {
    for &n in LENGTHS {
        let a = data32(n, 2);
        let want: f64 = a.iter().map(|&x| f64::from(x)).sum();
        let got = f64::from(kernels::sum(&a));
        assert!(
            (got - want).abs() <= 1e-4 * want.abs().max(1.0),
            "n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn dot_f64_bitwise_matches_sequential_reference() {
    for &n in LENGTHS {
        let a = data64(n, 3);
        let b = data64(n, 4);
        let mut want = 0.0f64;
        for (&x, &y) in a.iter().zip(&b) {
            want += x * y;
        }
        assert_eq!(kernels::dot(&a, &b).to_bits(), want.to_bits(), "n={n}");
    }
}

#[test]
fn dot_f32_within_tolerance_of_f64_reference() {
    for &n in LENGTHS {
        let a = data32(n, 5);
        let b = data32(n, 6);
        let want: f64 =
            a.iter().zip(&b).map(|(&x, &y)| f64::from(x) * f64::from(y)).sum();
        let got = f64::from(kernels::dot(&a, &b));
        assert!(
            (got - want).abs() <= 1e-3 * want.abs().max(1.0),
            "n={n}: {got} vs {want}"
        );
    }
}

#[test]
fn nrm2_matches_sqrt_of_dot() {
    for &n in LENGTHS {
        let a = data64(n, 7);
        let want = kernels::dot(&a, &a).sqrt();
        assert_eq!(kernels::nrm2(&a).to_bits(), want.to_bits(), "n={n}");
    }
    let a32 = data32(33, 8);
    let want = f64::from(kernels::dot(&a32, &a32)).sqrt() as f32;
    assert_eq!(kernels::nrm2(&a32).to_bits(), want.to_bits());
}

#[test]
fn axpy_bitwise_matches_reference_on_both_lanes() {
    for &n in LENGTHS {
        let x = data64(n, 9);
        let y0 = data64(n, 10);
        let a = 1.37f64;
        let mut got = y0.clone();
        kernels::axpy(a, &x, &mut got);
        for i in 0..n {
            assert_eq!(got[i].to_bits(), (y0[i] + a * x[i]).to_bits(), "n={n} i={i}");
        }
        // Elementwise kernels are bitwise on f32 too — no reduction to
        // reassociate.
        let x32 = data32(n, 9);
        let y32 = data32(n, 10);
        let mut got32 = y32.clone();
        kernels::axpy(0.5f32, &x32, &mut got32);
        for i in 0..n {
            assert_eq!(got32[i].to_bits(), (y32[i] + 0.5 * x32[i]).to_bits());
        }
    }
}

#[test]
fn sub_and_sub_scalar_bitwise_match_reference() {
    for &n in LENGTHS {
        let a = data64(n, 11);
        let b = data64(n, 12);
        let mut out = vec![0.0f64; n];
        kernels::sub(&a, &b, &mut out);
        for i in 0..n {
            assert_eq!(out[i].to_bits(), (a[i] - b[i]).to_bits(), "sub n={n} i={i}");
        }
        let mut y = a.clone();
        kernels::sub_scalar(&mut y, 0.311);
        for i in 0..n {
            assert_eq!(y[i].to_bits(), (a[i] - 0.311).to_bits(), "sub_scalar n={n} i={i}");
        }
    }
}

#[test]
fn shrink_axpy_bitwise_matches_legacy_two_loop_update() {
    for &n in LENGTHS {
        if n == 0 {
            // Degenerate coordinate with an empty suffix still updates.
            let mut r: Vec<f64> = vec![];
            let (new, delta) = kernels::shrink_axpy(&mut r, 0.5, 1.0, 2.0, 0.1, 1.0);
            assert_eq!(new, kernels::shrink(0.5f64 * 0.0 + 1.0 * 2.0, 0.1));
            assert_eq!(delta, new - 2.0);
            continue;
        }
        let base = data64(n, 13);
        let (dj, alpha_j, lambda1) = (0.41f64, 0.9f64, 0.05f64);
        let cj = dj * dj * n as f64;
        let denom = cj;
        // Legacy: strict suffix loop, threshold, then a separate
        // correction loop recomputing dj*delta each row.
        let mut r_ref = base.clone();
        let mut suffix = 0.0f64;
        for ri in &r_ref {
            suffix += *ri;
        }
        let rho = suffix * dj + cj * alpha_j;
        let new_ref = kernels::shrink(rho, lambda1) / denom;
        let delta_ref = new_ref - alpha_j;
        if delta_ref != 0.0 {
            for ri in &mut r_ref {
                *ri -= dj * delta_ref;
            }
        }
        let mut r = base.clone();
        let (new, delta) = kernels::shrink_axpy(&mut r, dj, cj, alpha_j, lambda1, denom);
        assert_eq!(new.to_bits(), new_ref.to_bits(), "n={n}");
        assert_eq!(delta.to_bits(), delta_ref.to_bits(), "n={n}");
        for i in 0..n {
            assert_eq!(r[i].to_bits(), r_ref[i].to_bits(), "n={n} i={i}");
        }
    }
}

#[test]
fn shrink_matches_piecewise_definition() {
    for x in [-3.0f64, -1.0, -0.5, 0.0, 0.5, 1.0, 3.0] {
        let want = if x > 1.0 {
            x - 1.0
        } else if x < -1.0 {
            x + 1.0
        } else {
            0.0
        };
        assert_eq!(kernels::shrink(x, 1.0), want);
    }
}

#[test]
fn scatter_and_gather_kernels_match_references() {
    for &n in LENGTHS {
        let mut buf = data64(n, 14);
        kernels::scatter_levels(&mut buf, 2.25);
        assert!(buf.iter().all(|&x| x == 2.25), "n={n}");

        let k = 7usize;
        let idx: Vec<u32> = (0..n).map(|i| ((i * 5) % k) as u32).collect();
        let levels: Vec<f64> = (0..k).map(|i| i as f64 * 0.5 - 1.0).collect();
        let want_gather: Vec<f64> = idx.iter().map(|&i| levels[i as usize]).collect();
        assert_eq!(kernels::gather_levels(&levels, &idx), want_gather, "n={n}");

        let mut want_counts = vec![0usize; k];
        for &i in &idx {
            want_counts[i as usize] += 1;
        }
        assert_eq!(kernels::gather_counts(&idx, k), want_counts, "n={n}");

        let inverse: Vec<usize> = (0..n).map(|i| (i * 3) % k.min(n.max(1))).collect();
        let table: Vec<u32> = (0..k.min(n.max(1))).map(|i| (i * 10) as u32).collect();
        let want_idx: Vec<u32> = inverse.iter().map(|&j| table[j]).collect();
        assert_eq!(kernels::gather_indices(&table, &inverse), want_idx, "n={n}");
    }
}

#[test]
fn gather_sq_loss_bitwise_matches_sequential_reference_on_both_lanes() {
    for &n in LENGTHS {
        let orig = data64(n, 15);
        let m = n.max(1).min(9);
        let inverse: Vec<usize> = (0..n).map(|i| (i * 7) % m).collect();
        let lv: Vec<f64> = (0..m).map(|i| i as f64 * 0.3 - 1.0).collect();
        let mut want = 0.0f64;
        for (o, &j) in orig.iter().zip(&inverse) {
            let d = *o - lv[j];
            want += d * d;
        }
        assert_eq!(
            kernels::gather_sq_loss(&orig, &inverse, &lv).to_bits(),
            want.to_bits(),
            "n={n}"
        );
        // The loss kernel is strict on the f32 lane too (shared f64
        // accumulator contract with types::finalize).
        let orig32 = data32(n, 15);
        let lv32: Vec<f32> = lv.iter().map(|&x| x as f32).collect();
        let mut want32 = 0.0f64;
        for (o, &j) in orig32.iter().zip(&inverse) {
            let d = f64::from(*o - lv32[j]);
            want32 += d * d;
        }
        assert_eq!(
            kernels::gather_sq_loss(&orig32, &inverse, &lv32).to_bits(),
            want32.to_bits(),
            "n={n} f32"
        );
    }
}

#[test]
fn packed_indices_roundtrip_at_bit_width_steps() {
    // The k values the satellite names: both sides of each ⌈log₂ k⌉ step,
    // plus the 16-bit plane.
    for k in [1usize, 2, 3, 255, 256, 257, 65536] {
        // k = 1 packs to the zero-bit degenerate plane (no index bits).
        let want_bits = kernels::packed_bits_for(k);
        for n in [0usize, 1, 7, 64, 71, 500] {
            let idx: Vec<u32> = (0..n).map(|i| ((i * 2654435761usize) % k) as u32).collect();
            let p = PackedIndices::pack(&idx, k);
            assert_eq!(p.bits(), want_bits, "k={k}");
            assert_eq!(p.len(), n, "k={k} n={n}");
            assert_eq!(p.unpack(), idx, "k={k} n={n}");
            assert_eq!(p.packed_bytes(), (n * want_bits as usize).div_ceil(8));
            for (i, &want) in idx.iter().enumerate() {
                assert_eq!(p.get(i), want, "k={k} n={n} get({i})");
            }
        }
    }
}

#[test]
fn packed_codebook_roundtrips_through_jsonio() {
    for k in [1usize, 2, 3, 255, 256, 257] {
        let values: Vec<f64> = (0..600).map(|i| ((i * 13) % k) as f64).collect();
        let cb = Codebook::from_values(&values).unwrap();
        let packed = cb.pack();
        let wire = sqlsq::jsonio::packed_codebook_to_json(&packed, vec![]).to_string();
        let back =
            sqlsq::jsonio::packed_codebook_from_json(&sqlsq::jsonio::parse(&wire).unwrap())
                .unwrap();
        assert_eq!(back, packed, "k={k}");
        assert_eq!(back.to_codebook(), cb, "k={k}");
        // Honest accounting: the packed form stores exactly ⌈log₂ k⌉ bits
        // (zero when a single level makes every index 0).
        let stats = packed.stats(k);
        assert_eq!(stats.bits_per_idx_stored, kernels::packed_bits_for(cb.k()));
        assert_eq!(stats.bits_per_idx_packed, stats.bits_per_idx_stored);
    }
}
