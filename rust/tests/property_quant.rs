//! Property tests (testkit) over the core library invariants — the
//! DESIGN §8 list.

use sqlsq::linalg::stats::{distinct_count_exact, l2_loss};
use sqlsq::quant::{
    self, lasso, refit, unique::UniqueDecomp, vmatrix::VBasis, QuantMethod, QuantOptions,
};
use sqlsq::testkit::{check, gens};

const CASES: usize = 40;

fn decomp(data: &[f64]) -> (UniqueDecomp, VBasis) {
    let u = UniqueDecomp::new(data).unwrap();
    let b = VBasis::new(&u.values);
    (u, b)
}

#[test]
fn prop_recover_unique_is_identity() {
    check("recover∘unique = id", CASES, gens::vec_f64(1..=200, -50.0, 50.0), |xs| {
        let u = UniqueDecomp::new(xs).map_err(|e| e.to_string())?;
        let rec = u.recover(&u.values).map_err(|e| e.to_string())?;
        if rec == *xs {
            Ok(())
        } else {
            Err("reconstruction differs".into())
        }
    });
}

#[test]
fn prop_structured_v_ops_match_dense() {
    check("V ops ≡ dense", CASES, gens::vec_f64(2..=100, -10.0, 10.0), |xs| {
        let (u, b) = decomp(xs);
        let alpha: Vec<f64> = (0..u.m()).map(|i| ((i * 7 % 5) as f64) - 2.0).collect();
        let fast = b.apply(&alpha);
        let slow = b.dense().matvec(&alpha).unwrap();
        for (f, s) in fast.iter().zip(&slow) {
            if (f - s).abs() > 1e-8 {
                return Err(format!("apply mismatch {f} vs {s}"));
            }
        }
        let r: Vec<f64> = u.values.iter().map(|v| v.sin()).collect();
        let fast_t = b.t_apply(&r);
        let slow_t = b.dense().t_matvec(&r).unwrap();
        for (f, s) in fast_t.iter().zip(&slow_t) {
            if (f - s).abs() > 1e-8 {
                return Err(format!("t_apply mismatch {f} vs {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cd_objective_never_increases() {
    check("CD objective monotone", CASES, gens::vec_f64(2..=80, -5.0, 5.0), |xs| {
        let (u, b) = decomp(xs);
        let cfg = lasso::LassoConfig { lambda1: 0.1, max_epochs: 1, tol: 0.0, ..Default::default() };
        let mut alpha: Option<Vec<f64>> = None;
        let mut prev = f64::INFINITY;
        for _ in 0..5 {
            let sol = lasso::solve(&b, &u.values, &cfg, alpha.as_deref())
                .map_err(|e| e.to_string())?;
            if sol.objective > prev + 1e-9 {
                return Err(format!("objective rose {prev} -> {}", sol.objective));
            }
            prev = sol.objective;
            alpha = Some(sol.alpha);
        }
        Ok(())
    });
}

#[test]
fn prop_refit_never_increases_loss() {
    check("refit ≤ raw l1 loss", CASES, gens::vec_clustered(4..=120, 5), |xs| {
        let (u, b) = decomp(xs);
        let cfg = lasso::LassoConfig { lambda1: 0.3, ..Default::default() };
        let sol = lasso::solve(&b, &u.values, &cfg, None).map_err(|e| e.to_string())?;
        let support = sol.support();
        if support.is_empty() {
            return Ok(());
        }
        let raw = l2_loss(&b.apply(&sol.alpha), &u.values);
        let re = refit::refit_fast(&b, &u.values, &support, None).map_err(|e| e.to_string())?;
        let refit_loss = l2_loss(&re.reconstruction, &u.values);
        if refit_loss <= raw + 1e-9 {
            Ok(())
        } else {
            Err(format!("refit {refit_loss} > raw {raw}"))
        }
    });
}

#[test]
fn prop_count_methods_respect_target() {
    check(
        "count methods ≤ target",
        CASES,
        gens::vec_with_target(2..=150, 12),
        |(xs, t)| {
            for method in [
                QuantMethod::KMeans,
                QuantMethod::ClusterLs,
                QuantMethod::KMeansExact,
                QuantMethod::Gmm,
                QuantMethod::L0,
                QuantMethod::IterativeL1,
            ] {
                let opts = QuantOptions {
                    target_values: *t,
                    lambda1: 1e-3,
                    ..Default::default()
                };
                let out = quant::quantize(xs, method, &opts).map_err(|e| e.to_string())?;
                if out.distinct_values() > *t {
                    return Err(format!(
                        "{} produced {} > target {t}",
                        method.id(),
                        out.distinct_values()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantized_distinct_never_exceeds_input() {
    check(
        "output distinct ≤ input distinct",
        CASES,
        gens::vec_clustered(2..=100, 4),
        |xs| {
            let m_in = distinct_count_exact(xs);
            for method in [QuantMethod::L1, QuantMethod::L1LeastSquare, QuantMethod::KMeans] {
                let opts = QuantOptions { lambda1: 0.05, target_values: 6, ..Default::default() };
                let out = quant::quantize(xs, method, &opts).map_err(|e| e.to_string())?;
                if out.distinct_values() > m_in {
                    return Err(format!(
                        "{}: {} distinct out of {m_in} in",
                        method.id(),
                        out.distinct_values()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_equal_inputs_map_to_equal_outputs() {
    check("ties preserved", CASES, gens::vec_clustered(2..=60, 3), |xs| {
        // Duplicate the vector so every value has multiplicity ≥ 2.
        let mut doubled = xs.clone();
        doubled.extend_from_slice(xs);
        let opts = QuantOptions { target_values: 4, lambda1: 0.1, ..Default::default() };
        for method in [QuantMethod::KMeans, QuantMethod::L1LeastSquare, QuantMethod::ClusterLs] {
            let out = quant::quantize(&doubled, method, &opts).map_err(|e| e.to_string())?;
            let n = xs.len();
            for i in 0..n {
                if out.values[i] != out.values[i + n] {
                    return Err(format!("{}: tie broken at {i}", method.id()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cluster_ls_beats_unweighted_kmeans_on_unique_loss() {
    // Algorithm 3 dominance (paper §3.5): LS-optimal values for the chosen
    // partition can only match or beat the same partition with centroid
    // values, measured on ŵ.
    check(
        "cluster_ls ≤ kmeans (ŵ loss)",
        CASES,
        gens::vec_clustered(6..=120, 6),
        |xs| {
            let (u, b) = decomp(xs);
            let km_cfg = sqlsq::cluster::kmeans::KMeansConfig { k: 5, seed: 1, ..Default::default() };
            let cls = quant::cluster_ls::solve_cluster_ls(
                &b,
                &u.values,
                None,
                &quant::cluster_ls::ClusterLsConfig {
                    l: 5,
                    kmeans: km_cfg.clone(),
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let (km_rec, _, _) =
                quant::cluster_ls::kmeans_quantize_levels(&b, None, &km_cfg)
                    .map_err(|e| e.to_string())?;
            let ls = l2_loss(&cls.reconstruction, &u.values);
            let km = l2_loss(&km_rec, &u.values);
            if ls <= km + 1e-9 {
                Ok(())
            } else {
                Err(format!("cluster_ls {ls} > kmeans {km}"))
            }
        },
    );
}

#[test]
fn prop_clamp_forces_range() {
    check("clamp ⇒ in range", CASES, gens::vec_f64(1..=80, -3.0, 3.0), |xs| {
        let opts = QuantOptions {
            target_values: 5,
            lambda1: 0.2,
            clamp: Some((-1.0, 1.0)),
            ..Default::default()
        };
        for method in [QuantMethod::KMeans, QuantMethod::L1, QuantMethod::Gmm] {
            let out = quant::quantize(xs, method, &opts).map_err(|e| e.to_string())?;
            if let Some(bad) = out.values.iter().find(|&&v| !(-1.0..=1.0).contains(&v)) {
                return Err(format!("{}: value {bad} escaped the clamp", method.id()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_f32_lane_loss_tracks_f64_lane() {
    // The two precision lanes optimize the same objective from the same
    // start; their reported losses must agree to ~1e-3 in the regime where
    // the loss is meaningful (the absolute `1e-3·(1+loss)` form mirrors
    // the warm-vs-cold sweep tolerance — both compare runs whose CD
    // trajectories, and hence tie-coordinates, may differ slightly).
    check(
        "f32 lane loss ≈ f64 lane loss",
        CASES,
        gens::vec_clustered(8..=120, 5),
        |xs| {
            let xs32: Vec<f32> = xs.iter().map(|&x| x as f32).collect();
            for method in [QuantMethod::L1, QuantMethod::L1LeastSquare] {
                let opts = QuantOptions { lambda1: 0.05, ..Default::default() };
                let o64 = quant::quantize(xs, method, &opts).map_err(|e| e.to_string())?;
                let o32 = quant::quantize_f32(&xs32, method, &opts).map_err(|e| e.to_string())?;
                let tol = 1e-3 * (1.0 + o64.l2_loss);
                if (o32.l2_loss - o64.l2_loss).abs() > tol {
                    return Err(format!(
                        "{}: f32 loss {} vs f64 loss {}",
                        method.id(),
                        o32.l2_loss,
                        o64.l2_loss
                    ));
                }
                // Level counts stay in the same ballpark (ties can shift a
                // few marginal coordinates either way).
                let (d64, d32) = (o64.distinct_values(), o32.distinct_values());
                if d64.abs_diff(d32) > 2 + d64.max(d32) / 4 {
                    return Err(format!("{}: {d32} f32 levels vs {d64} f64", method.id()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f32_f64_lasso_supports_agree_up_to_ties() {
    // Same support up to ties: the lanes may disagree only on marginal
    // coordinates (near the KKT boundary |ρ| ≈ λ₁), whose reconstruction
    // contribution |α_j·d_j| is necessarily small in whichever lane kept
    // them.
    check(
        "f32/f64 lasso support ≡ up to ties",
        CASES,
        gens::vec_clustered(8..=100, 5),
        |xs| {
            let (u64d, b64) = decomp(xs);
            let xs32: Vec<f32> = xs.iter().map(|&x| x as f32).collect();
            let u32d = UniqueDecomp::new(&xs32).map_err(|e| e.to_string())?;
            if u32d.m() != u64d.m() {
                // Narrowing merged two adjacent levels — documented lane
                // behaviour, not a support property; skip this case.
                return Ok(());
            }
            let b32 = VBasis::new(&u32d.values);
            let cfg = lasso::LassoConfig { lambda1: 0.2, ..Default::default() };
            let s64 = lasso::solve(&b64, &u64d.values, &cfg, None).map_err(|e| e.to_string())?;
            let s32 = lasso::solve(&b32, &u32d.values, &cfg, None).map_err(|e| e.to_string())?;
            let m = u64d.m();
            let in64: Vec<bool> = s64.alpha.iter().map(|&a| a != 0.0).collect();
            let in32: Vec<bool> = s32.alpha.iter().map(|&a| a != 0.0).collect();
            let mut flips = 0usize;
            for j in 0..m {
                if in64[j] == in32[j] {
                    continue;
                }
                flips += 1;
                // The lane that kept j must hold it with a near-zero
                // contribution — a tie, not a disagreement.
                let contrib = if in64[j] {
                    s64.alpha[j] * b64.diffs()[j]
                } else {
                    f64::from(s32.alpha[j]) * f64::from(b32.diffs()[j])
                }
                .abs();
                if contrib > 5e-2 {
                    return Err(format!(
                        "coordinate {j} flipped with contribution {contrib:.3e}"
                    ));
                }
            }
            if flips > 2 + m / 5 {
                return Err(format!("{flips} support flips out of m={m}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_l2_loss_reported_matches_recomputation() {
    check("reported loss is correct", CASES, gens::vec_f64(1..=100, 0.0, 10.0), |xs| {
        let opts = QuantOptions { target_values: 4, ..Default::default() };
        let out = quant::quantize(xs, QuantMethod::KMeans, &opts).map_err(|e| e.to_string())?;
        let recomputed = l2_loss(xs, &out.values);
        if (recomputed - out.l2_loss).abs() < 1e-9 * (1.0 + recomputed) {
            Ok(())
        } else {
            Err(format!("loss {} vs recomputed {recomputed}", out.l2_loss))
        }
    });
}
