"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

Three graphs are AOT-lowered per shape bucket (see aot.py):

* ``lasso_cd_epochs``  — `EPOCHS_PER_CALL` CD epochs over the difference
  basis (eq 6/13); the Rust coordinator chains calls and owns the
  convergence test, so one artifact serves every λ and every warm start.
* ``kmeans_lloyd``     — `LLOYD_ITERS_PER_CALL` fused Lloyd steps.
* ``mlp_forward``      — the 784-256-128-64-10 forward pass for the
  §4.1 post-quantization accuracy evaluation (batched).

Performance notes (DESIGN §9): epochs are chained with
``lax.fori_loop`` so nothing is rematerialized between epochs; all
weights are passed as arguments (no constants baked in) so one compiled
executable serves every model/λ; everything is f32.
"""

import jax
import jax.numpy as jnp

from compile.kernels import gmm, lasso_cd, kmeans, mlp as mlp_kernels

# Iterations fused into one executable call. Chosen so PJRT dispatch
# overhead amortizes without making the artifact's unrolled loop huge —
# the §Perf sweep in EXPERIMENTS.md justifies the values.
EPOCHS_PER_CALL = 8
LLOYD_ITERS_PER_CALL = 4
EM_ITERS_PER_CALL = 4

#: The paper's architecture (§4.1).
MLP_DIMS = [784, 256, 128, 64, 10]


def lasso_cd_epochs(w, d, cw, lam, alpha):
    """EPOCHS_PER_CALL structured CD epochs (kernel-backed)."""

    def body(_, a):
        return lasso_cd.lasso_cd_epoch(w, d, cw, lam, a)

    return jax.lax.fori_loop(0, EPOCHS_PER_CALL, body, alpha)


def kmeans_lloyd(points, cw, centroids):
    """LLOYD_ITERS_PER_CALL fused Lloyd steps (kernel-backed)."""

    def body(_, c):
        return kmeans.kmeans_step(points, cw, c)

    return jax.lax.fori_loop(0, LLOYD_ITERS_PER_CALL, body, centroids)


def gmm_em(points, cw, means, variances, weights, var_floor):
    """EM_ITERS_PER_CALL fused EM steps (kernel-backed)."""

    def body(_, state):
        mu, var, pi = state
        return gmm.gmm_em_step(points, cw, mu, var, pi, var_floor)

    return jax.lax.fori_loop(
        0, EM_ITERS_PER_CALL, body, (means, variances, weights)
    )


def gmm_example_args(m, k):
    """ShapeDtypeStructs for one gmm_em lowering."""
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((k,), f32),
        jax.ShapeDtypeStruct((k,), f32),
        jax.ShapeDtypeStruct((k,), f32),
        jax.ShapeDtypeStruct((), f32),
    ]


def mlp_forward(x, w1, b1, w2, b2, w3, b3, w4, b4):
    """Forward pass of the paper's MLP (kernel-backed, logits out)."""
    h = mlp_kernels.dense(x, w1, b1, relu=True)
    h = mlp_kernels.dense(h, w2, b2, relu=True)
    h = mlp_kernels.dense(h, w3, b3, relu=True)
    return mlp_kernels.dense(h, w4, b4, relu=False)


def mlp_example_args(batch):
    """ShapeDtypeStructs for one mlp_forward lowering."""
    f32 = jnp.float32
    args = [jax.ShapeDtypeStruct((batch, MLP_DIMS[0]), f32)]
    for i in range(4):
        args.append(jax.ShapeDtypeStruct((MLP_DIMS[i], MLP_DIMS[i + 1]), f32))
        args.append(jax.ShapeDtypeStruct((MLP_DIMS[i + 1],), f32))
    return args


def lasso_example_args(m):
    """ShapeDtypeStructs for one lasso_cd_epochs lowering."""
    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((m,), f32)
    return [vec, vec, vec, jax.ShapeDtypeStruct((2,), f32), vec]


def kmeans_example_args(m, k):
    """ShapeDtypeStructs for one kmeans_lloyd lowering."""
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((m,), f32),
        jax.ShapeDtypeStruct((k,), f32),
    ]
