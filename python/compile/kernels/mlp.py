"""L1 Pallas kernel: fused dense + bias + ReLU layer.

Used by the L2 MLP forward graph (§4.1 accuracy evaluation on the
serving path).  The matmul is tiled with `BlockSpec` for the 128×128 MXU
shape: grid over (batch tiles × output tiles), the full contraction
dimension resident per step — for the paper's 784-256-128-64-10 network
every K fits VMEM (784·128·4 B ≈ 0.4 MiB per operand tile).  On a real
TPU this kernel would run in bf16 on the MXU; interpret mode validates
the numerics on CPU (DESIGN §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 32
TILE_N = 64


def _dense_body(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]          # [TM, K]
    w = w_ref[...]          # [K, TN]
    b = b_ref[...]          # [TN]
    z = jnp.dot(x, w) + b[None, :]
    if relu:
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("relu",))
def dense(x, w, b, relu=True):
    """Fused y = relu?(x @ w + b) with MXU-shaped tiling.

    Args:
      x: f32[M, K] activations (M divisible by TILE_M after bucketing).
      w: f32[K, N] weights (N divisible by TILE_N, or smaller than it).
      b: f32[N]    bias.
      relu: apply ReLU (static).

    Returns:
      f32[M, N].
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    tm = TILE_M if m % TILE_M == 0 else m
    tn = TILE_N if n % TILE_N == 0 else n
    grid = (m // tm, n // tn)
    kernel = functools.partial(_dense_body, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
