"""Pure-jnp correctness oracles for every Pallas kernel.

These are the contract: pytest (+hypothesis) asserts the kernels match
them with ``assert_allclose``.  They are written for clarity over speed
— the sequential CD semantics in particular are spelled out coordinate
by coordinate.
"""

import jax
import jax.numpy as jnp


def lasso_cd_epoch_ref(w, d, cw, lam, alpha):
    """Reference weighted Gauss-Seidel CD epoch (descending order).

    Mirrors rust/src/quant/lasso.rs::solve exactly: the residual is
    maintained explicitly (O(m) per coordinate, O(m²) per epoch) so any
    disagreement with the O(m) lazy-scalar kernel is a kernel bug.
    """
    w = jnp.asarray(w)
    d = jnp.asarray(d)
    cw = jnp.asarray(cw)
    alpha = jnp.asarray(alpha)
    lam1, lam2 = lam[0], lam[1]
    m = w.shape[0]
    rec = jnp.cumsum(d * alpha)
    r = w - rec

    def body(jj, carry):
        alpha, r = carry
        j = m - 1 - jj
        dj = d[j]
        # Column norm over rows ≥ j with row weights.
        mask = jnp.arange(m) >= j
        cj = dj * dj * jnp.sum(jnp.where(mask, cw, 0.0))
        rho = dj * jnp.sum(jnp.where(mask, cw * r, 0.0)) + cj * alpha[j]
        denom = cj - 2.0 * lam2
        denom = jnp.where(denom > 0.0, denom, cj)  # per-coordinate l1 fallback
        shrunk = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lam1, 0.0)
        new = shrunk / jnp.where(denom > 0.0, denom, 1.0)
        ok = cj > 0.0
        new = jnp.where(ok, new, alpha[j])
        delta = new - alpha[j]
        r = r - jnp.where(mask, dj * delta, 0.0)
        alpha = alpha.at[j].set(new)
        return alpha, r

    alpha, _ = jax.lax.fori_loop(0, m, body, (alpha, r))
    return alpha


def kmeans_accumulate_ref(points, cw, centroids):
    """Reference assign + accumulate."""
    d2 = (points[:, None] - centroids[None, :]) ** 2
    a = jnp.argmin(d2, axis=1)
    k = centroids.shape[0]
    onehot = (a[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    sums = jnp.sum(onehot * (cw * points)[:, None], axis=0)
    wsums = jnp.sum(onehot * cw[:, None], axis=0)
    return sums, wsums


def kmeans_step_ref(points, cw, centroids):
    """Reference full Lloyd step with empty-cluster hold + sort."""
    sums, wsums = kmeans_accumulate_ref(points, cw, centroids)
    new = jnp.where(wsums > 0.0, sums / jnp.where(wsums > 0.0, wsums, 1.0), centroids)
    return jnp.sort(new)


def gmm_accumulate_ref(points, cw, means, variances, weights):
    """Reference E-step sufficient statistics (log-space)."""
    x = jnp.asarray(points)
    d = x[:, None] - jnp.asarray(means)[None, :]
    var = jnp.asarray(variances)
    logp = (
        -0.5 * (d * d / var[None, :] + jnp.log(var)[None, :]
                + jnp.log(2.0 * jnp.pi))
        + jnp.log(jnp.maximum(jnp.asarray(weights), 1e-30))[None, :]
    )
    lse = jax.scipy.special.logsumexp(logp, axis=1, keepdims=True)
    r = jnp.exp(logp - lse) * jnp.asarray(cw)[:, None]
    return jnp.sum(r, axis=0), jnp.sum(r * x[:, None], axis=0), jnp.sum(r * (x * x)[:, None], axis=0)


def dense_ref(x, w, b, relu=True):
    """Reference fused dense layer."""
    z = x @ w + b[None, :]
    return jnp.maximum(z, 0.0) if relu else z


def mlp_forward_ref(x, params):
    """Reference MLP forward over [(w, b), ...] with ReLU on all but last."""
    h = x
    for i, (w, b) in enumerate(params):
        h = dense_ref(h, w, b, relu=(i + 1 < len(params)))
    return h
