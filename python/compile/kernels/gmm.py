"""L1 Pallas kernel: fused GMM E+M accumulation (one EM step).

The paper's second baseline (soft weight-sharing, refs [15][16]) has the
same hot-loop shape as k-means: an O(m·k) responsibility computation.
The kernel tiles points into VMEM blocks, computes log-space
responsibilities against the (tiny, fully VMEM-resident) component
parameters, and accumulates the M-step sufficient statistics
(Σr, Σr·x, Σr·x²) per component; the cheap O(k) M-step finalization
(divide, variance floor, renormalize, sort) happens in the L2 graph.

Padding: weight-0 points contribute nothing. Lowered with
``interpret=True`` (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
LOG2PI = 1.8378770664093453


def _estep_body(p_ref, cw_ref, mu_ref, var_ref, pi_ref, n_ref, sx_ref, sxx_ref):
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        n_ref[...] = jnp.zeros_like(n_ref)
        sx_ref[...] = jnp.zeros_like(sx_ref)
        sxx_ref[...] = jnp.zeros_like(sxx_ref)

    x = p_ref[...]          # [B]
    cw = cw_ref[...]        # [B]
    mu = mu_ref[...]        # [k]
    var = var_ref[...]      # [k]
    pi = pi_ref[...]        # [k]

    # log N(x | mu_c, var_c) + log pi_c, broadcast [B, k].
    d = x[:, None] - mu[None, :]
    logp = (
        -0.5 * (d * d / var[None, :] + jnp.log(var)[None, :] + LOG2PI)
        + jnp.log(jnp.maximum(pi, 1e-30))[None, :]
    )
    lse = jax.scipy.special.logsumexp(logp, axis=1, keepdims=True)
    r = jnp.exp(logp - lse) * cw[:, None]  # weighted responsibilities [B, k]

    n_ref[...] += jnp.sum(r, axis=0)
    sx_ref[...] += jnp.sum(r * x[:, None], axis=0)
    sxx_ref[...] += jnp.sum(r * (x * x)[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=())
def gmm_accumulate(points, cw, means, variances, weights):
    """Fused E-step + sufficient-statistic accumulation.

    Args:
      points:    f32[m] data (m divisible by BLOCK after bucketing).
      cw:        f32[m] multiplicities (0 = padding).
      means:     f32[k] component means.
      variances: f32[k] component variances (> 0).
      weights:   f32[k] mixing weights.

    Returns:
      (n f32[k], sx f32[k], sxx f32[k]) — Σr, Σr·x, Σr·x².
    """
    m = points.shape[0]
    k = means.shape[0]
    block = min(BLOCK, m)
    assert m % block == 0, f"m={m} must be a multiple of {block}"
    return pl.pallas_call(
        _estep_body,
        grid=(m // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, cw, means, variances, weights)


def gmm_em_step(points, cw, means, variances, weights, var_floor):
    """One full EM step: kernel accumulation + M-step finalization.

    Components whose responsibility mass underflows keep their parameters
    (the Rust side repairs/collapses as needed). Means are kept sorted
    with their variances/weights permuted alongside.
    """
    n, sx, sxx = gmm_accumulate(points, cw, means, variances, weights)
    total = jnp.sum(n)
    ok = n > 1e-12 * jnp.maximum(total, 1e-30)
    safe_n = jnp.where(ok, n, 1.0)
    new_mu = jnp.where(ok, sx / safe_n, means)
    new_var = jnp.where(ok, jnp.maximum(sxx / safe_n - new_mu * new_mu, var_floor), variances)
    new_pi = jnp.where(ok, n / jnp.maximum(total, 1e-30), weights)
    new_pi = new_pi / jnp.sum(new_pi)
    order = jnp.argsort(new_mu)
    return new_mu[order], new_var[order], new_pi[order]
