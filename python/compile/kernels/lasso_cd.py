"""L1 Pallas kernel: one weighted coordinate-descent LASSO epoch.

This is the compute hot-spot of the paper (eq 6/13–15): a full
Gauss-Seidel epoch over the structured difference basis `V`, in the O(m)
suffix-scalar form derived in DESIGN §3.  The kernel is single-program
(grid=()): for the bucketed sizes (m ≤ 1024, f32) the entire state —
`w`, `d`, `cw`, `alpha`, the residual and the running suffix scalar —
is ≈ 20 KiB, comfortably VMEM-resident on a real TPU; the epoch is a
scalar recurrence, so the roofline is memory latency, not MXU.  See
DESIGN §7 (Hardware-Adaptation).

Row weights `cw` implement shape-bucket padding: a padded row has
`cw = 0` and provably cannot move any coordinate (its residual never
enters a suffix sum).  Padded *coordinates* carry `d = 0` and are
skipped by the `c_j > 0` guard.

Must be lowered with ``interpret=True`` — real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _epoch_body(w_ref, d_ref, cw_ref, lam_ref, alpha_ref, out_ref):
    """One CD epoch. lam_ref holds [lambda1, lambda2]."""
    m = w_ref.shape[0]
    w = w_ref[...]
    d = d_ref[...]
    cw = cw_ref[...]
    lam1 = lam_ref[0]
    lam2 = lam_ref[1]
    alpha0 = alpha_ref[...]

    # Residual at epoch start: r = w − cumsum(d ⊙ α), weighted later.
    rec = jnp.cumsum(d * alpha0)
    r = w - rec

    # Suffix weight sums W_j = Σ_{i≥j} cw_i  (for column norms) — O(m).
    wsuf = jnp.cumsum(cw[::-1])[::-1]

    def body(jj, carry):
        # Descending pass: j = m−1 … 0, lazy scalar s = Σ_{i≥j} cw_i r_i.
        alpha, s = carry
        j = m - 1 - jj
        s = s + cw[j] * r[j]
        dj = d[j]
        cj = dj * dj * wsuf[j]
        # Unstable negative-l2 denominator falls back to the plain-l1 rule
        # per coordinate (mirrors rust lasso::Instability::Skip).
        denom = cj - 2.0 * lam2
        denom = jnp.where(denom > 0.0, denom, cj)
        rho = dj * s + cj * alpha[j]
        shrunk = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lam1, 0.0)
        new = shrunk / jnp.where(denom > 0.0, denom, 1.0)
        # Guard: skip null columns (padding / d_j = 0).
        ok = cj > 0.0
        new = jnp.where(ok, new, alpha[j])
        delta = new - alpha[j]
        # Update the suffix scalar for the residual change on rows i ≥ j.
        s = s - dj * delta * wsuf[j]
        alpha = alpha.at[j].set(new)
        return alpha, s

    alpha, _ = jax.lax.fori_loop(0, m, body, (alpha0, jnp.float32(0.0)))
    out_ref[...] = alpha


@functools.partial(jax.jit, static_argnames=())
def lasso_cd_epoch(w, d, cw, lam, alpha):
    """Run one CD epoch via the Pallas kernel (interpret mode).

    Args:
      w:     f32[m]  sorted unique values (padded rows repeat the last value).
      d:     f32[m]  first differences (0 for padded coordinates).
      cw:    f32[m]  row weights (1 real / 0 padding, or multiplicities).
      lam:   f32[2]  [lambda1, lambda2].
      alpha: f32[m]  current coefficients.

    Returns:
      f32[m] updated coefficients.
    """
    m = w.shape[0]
    return pl.pallas_call(
        _epoch_body,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(w, d, cw, lam, alpha)
