"""L1 Pallas kernel: fused k-means assign + accumulate (one Lloyd step).

The paper's baseline hot loop is the O(m·k) assignment.  The kernel tiles
the points into VMEM blocks (`BLOCK` points per grid step) while the
centroid vector — tiny for scalar quantization — stays wholly
VMEM-resident; each grid step computes the point×centroid distance
matrix by broadcast (a VPU kernel: 1-d data has no MXU work), takes the
argmin, and accumulates per-centroid weighted sums and weights into the
output accumulators.  This is the TPU re-think of what a CUDA port would
do with threadblocks + shared-memory reductions (DESIGN §7).

Padding: points with weight 0 fall out of every accumulator, so shape
buckets are exact.  The division (and empty-cluster handling) happens in
the L2 graph, not here.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _step_body(p_ref, cw_ref, c_ref, sum_ref, wsum_ref):
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    pts = p_ref[...]            # [BLOCK]
    cw = cw_ref[...]            # [BLOCK]
    cen = c_ref[...]            # [k]
    # [BLOCK, k] squared distances by broadcast; argmin over k.
    diff = pts[:, None] - cen[None, :]
    a = jnp.argmin(diff * diff, axis=1)  # [BLOCK]
    onehot = (a[:, None] == jnp.arange(cen.shape[0])[None, :]).astype(jnp.float32)
    sum_ref[...] += jnp.sum(onehot * (cw * pts)[:, None], axis=0)
    wsum_ref[...] += jnp.sum(onehot * cw[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=())
def kmeans_accumulate(points, cw, centroids):
    """Fused assign+accumulate over all points.

    Args:
      points:    f32[m]  data (m divisible by BLOCK after bucketing).
      cw:        f32[m]  per-point weights (0 = padding).
      centroids: f32[k]  current centroids.

    Returns:
      (sums f32[k], wsums f32[k]) — per-centroid Σ w·x and Σ w.
    """
    m = points.shape[0]
    k = centroids.shape[0]
    block = min(BLOCK, m)
    assert m % block == 0, f"m={m} must be a multiple of {block}"
    grid = (m // block,)
    return pl.pallas_call(
        _step_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, cw, centroids)


def kmeans_step(points, cw, centroids):
    """One full Lloyd step: accumulate via the kernel, then update +
    re-sort centroids (empty clusters keep their position)."""
    sums, wsums = kmeans_accumulate(points, cw, centroids)
    new = jnp.where(wsums > 0.0, sums / jnp.where(wsums > 0.0, wsums, 1.0), centroids)
    return jnp.sort(new)
