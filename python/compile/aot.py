"""AOT pipeline: lower every L2 graph to HLO *text* + write the manifest.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` via serialized
protos) is the interchange format: jax ≥ 0.5 emits HloModuleProtos with
64-bit instruction ids which xla_extension 0.5.1 (the version the
published ``xla`` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Outputs one ``<name>.hlo.txt`` per (graph, shape-bucket) plus
``manifest.json`` describing shapes/dtypes — the Rust runtime
(rust/src/runtime/artifact.rs) loads artifacts strictly through the
manifest.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

#: Shape buckets for the data-dependent dimension m = |unique(w)|
#: (padded like batch/sequence dims in a serving system; DESIGN §3).
LASSO_BUCKETS = [64, 256, 1024]
KMEANS_BUCKETS = [(256, 8), (256, 32), (1024, 8), (1024, 64)]
GMM_BUCKETS = [(256, 8), (1024, 32)]
MLP_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(s):
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def build_entries():
    """(name, jitted fn, example args) for every artifact."""
    entries = []
    for m in LASSO_BUCKETS:
        entries.append(
            (
                f"lasso_cd_m{m}",
                model.lasso_cd_epochs,
                model.lasso_example_args(m),
                {"kind": "lasso_cd", "m": m, "epochs_per_call": model.EPOCHS_PER_CALL},
            )
        )
    for m, k in KMEANS_BUCKETS:
        entries.append(
            (
                f"kmeans_m{m}_k{k}",
                model.kmeans_lloyd,
                model.kmeans_example_args(m, k),
                {
                    "kind": "kmeans",
                    "m": m,
                    "k": k,
                    "iters_per_call": model.LLOYD_ITERS_PER_CALL,
                },
            )
        )
    for m, k in GMM_BUCKETS:
        entries.append(
            (
                f"gmm_m{m}_k{k}",
                model.gmm_em,
                model.gmm_example_args(m, k),
                {
                    "kind": "gmm",
                    "m": m,
                    "k": k,
                    "iters_per_call": model.EM_ITERS_PER_CALL,
                },
            )
        )
    entries.append(
        (
            f"mlp_fwd_b{MLP_BATCH}",
            model.mlp_forward,
            model.mlp_example_args(MLP_BATCH),
            {"kind": "mlp_fwd", "batch": MLP_BATCH, "dims": model.MLP_DIMS},
        )
    )
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower only artifacts whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}
    for name, fn, example_args, meta in build_entries():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [_spec(s) for s in example_args],
                "meta": meta,
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
