"""Pallas GMM E-step kernel vs the pure-jnp oracle + EM-step behavior."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gmm, ref


def make_problem(m, k, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 100.0, size=m).astype(np.float32)
    cw = np.ones(m, dtype=np.float32)
    mu = np.sort(rng.uniform(0.0, 100.0, size=k)).astype(np.float32)
    var = rng.uniform(4.0, 50.0, size=k).astype(np.float32)
    pi = np.full(k, 1.0 / k, dtype=np.float32)
    return pts, cw, mu, var, pi


@pytest.mark.parametrize("m,k", [(256, 4), (256, 8), (512, 16), (1024, 32)])
def test_accumulate_matches_ref(m, k):
    pts, cw, mu, var, pi = make_problem(m, k, seed=m + k)
    n_k, sx_k, sxx_k = gmm.gmm_accumulate(pts, cw, mu, var, pi)
    n_r, sx_r, sxx_r = ref.gmm_accumulate_ref(pts, cw, mu, var, pi)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sx_k), np.asarray(sx_r), rtol=1e-4, atol=1e-1)
    np.testing.assert_allclose(np.asarray(sxx_k), np.asarray(sxx_r), rtol=1e-3, atol=1e1)


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=3),
    k=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_accumulate_hypothesis(blocks, k, seed):
    m = gmm.BLOCK * blocks
    pts, cw, mu, var, pi = make_problem(m, k, seed=seed)
    n_k, sx_k, _ = gmm.gmm_accumulate(pts, cw, mu, var, pi)
    n_r, sx_r, _ = ref.gmm_accumulate_ref(pts, cw, mu, var, pi)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(sx_k), np.asarray(sx_r), rtol=1e-3, atol=1.0)


def test_responsibilities_sum_to_total_weight():
    pts, cw, mu, var, pi = make_problem(512, 8, seed=1)
    n, _, _ = gmm.gmm_accumulate(pts, cw, mu, var, pi)
    assert abs(float(np.sum(np.asarray(n))) - 512.0) < 1e-2


def test_padding_weights_are_inert():
    pts, cw, mu, var, pi = make_problem(512, 8, seed=2)
    cw_pad = cw.copy()
    cw_pad[256:] = 0.0
    n_a, sx_a, _ = gmm.gmm_accumulate(pts[:256], cw[:256], mu, var, pi)
    n_b, sx_b, _ = gmm.gmm_accumulate(pts, cw_pad, mu, var, pi)
    np.testing.assert_allclose(np.asarray(n_b), np.asarray(n_a), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sx_b), np.asarray(sx_a), rtol=1e-5, atol=1e-2)


def test_em_converges_on_separated_modes():
    rng = np.random.default_rng(3)
    pts = np.concatenate(
        [rng.normal(10, 1.0, 128), rng.normal(90, 1.0, 128)]
    ).astype(np.float32)
    cw = np.ones(256, dtype=np.float32)
    mu = np.array([30.0, 60.0], dtype=np.float32)
    var = np.array([100.0, 100.0], dtype=np.float32)
    pi = np.array([0.5, 0.5], dtype=np.float32)
    floor = np.float32(1e-4)
    for _ in range(10):
        mu, var, pi = gmm.gmm_em_step(pts, cw, mu, var, pi, floor)
    mu = np.asarray(mu)
    np.testing.assert_allclose(mu, [10.0, 90.0], atol=1.0)
    assert np.all(np.asarray(var) < 5.0)
    np.testing.assert_allclose(np.asarray(pi), [0.5, 0.5], atol=0.05)


def test_em_step_keeps_simplex_and_order():
    pts, cw, mu, var, pi = make_problem(256, 8, seed=4)
    mu2, var2, pi2 = gmm.gmm_em_step(pts, cw, mu, var, pi, np.float32(1e-4))
    mu2, var2, pi2 = map(np.asarray, (mu2, var2, pi2))
    assert abs(float(pi2.sum()) - 1.0) < 1e-5
    assert np.all(np.diff(mu2) >= 0), "means must stay sorted"
    assert np.all(var2 >= 1e-4 - 1e-7), "variance floor must hold"


def test_fused_em_graph_matches_manual_steps():
    pts, cw, mu, var, pi = make_problem(256, 8, seed=5)
    floor = np.float32(1e-4)
    fused = model.gmm_em(pts, cw, mu, var, pi, floor)
    manual = (mu, var, pi)
    for _ in range(model.EM_ITERS_PER_CALL):
        manual = gmm.gmm_em_step(pts, cw, *manual, floor)
    for a, b in zip(fused, manual):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=1e-2)
