"""Pallas kmeans kernel vs the pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kmeans, ref


def make_problem(m, k, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 100.0, size=m).astype(np.float32)
    cw = np.ones(m, dtype=np.float32)
    cen = np.sort(rng.uniform(0.0, 100.0, size=k)).astype(np.float32)
    return pts, cw, cen


@pytest.mark.parametrize("m,k", [(256, 4), (256, 16), (512, 8), (1024, 32)])
def test_accumulate_matches_ref(m, k):
    pts, cw, cen = make_problem(m, k, seed=m + k)
    s_k, w_k = kmeans.kmeans_accumulate(pts, cw, cen)
    s_r, w_r = ref.kmeans_accumulate_ref(pts, cw, cen)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    k=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_accumulate_hypothesis(blocks, k, seed):
    m = kmeans.BLOCK * blocks
    pts, cw, cen = make_problem(m, k, seed=seed)
    s_k, w_k = kmeans.kmeans_accumulate(pts, cw, cen)
    s_r, w_r = ref.kmeans_accumulate_ref(pts, cw, cen)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), rtol=1e-6, atol=1e-6)


def test_step_matches_ref():
    pts, cw, cen = make_problem(512, 8, seed=1)
    new_k = np.asarray(kmeans.kmeans_step(pts, cw, cen))
    new_r = np.asarray(ref.kmeans_step_ref(pts, cw, cen))
    np.testing.assert_allclose(new_k, new_r, rtol=1e-5, atol=1e-4)
    assert np.all(np.diff(new_k) >= 0), "centroids must stay sorted"


def test_padding_weights_are_inert():
    pts, cw, cen = make_problem(512, 8, seed=2)
    cw_padded = cw.copy()
    cw_padded[256:] = 0.0
    s_full, w_full = kmeans.kmeans_accumulate(pts[:256], cw[:256], cen)
    s_pad, w_pad = kmeans.kmeans_accumulate(pts, cw_padded, cen)
    np.testing.assert_allclose(np.asarray(s_pad), np.asarray(s_full), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(w_pad), np.asarray(w_full), rtol=1e-6, atol=1e-6)


def test_empty_cluster_keeps_centroid():
    pts = np.full(256, 10.0, dtype=np.float32)
    cw = np.ones(256, dtype=np.float32)
    cen = np.array([10.0, 99.0], dtype=np.float32)  # nobody picks 99
    new = np.asarray(kmeans.kmeans_step(pts, cw, cen))
    assert 99.0 in new, f"empty cluster must hold its position, got {new}"


def test_lloyd_converges_on_separated_data():
    rng = np.random.default_rng(3)
    pts = np.concatenate(
        [rng.normal(10, 0.2, 128), rng.normal(50, 0.2, 64), rng.normal(90, 0.2, 64)]
    ).astype(np.float32)
    cw = np.ones(256, dtype=np.float32)
    cen = np.array([20.0, 40.0, 80.0], dtype=np.float32)
    for _ in range(10):
        cen = kmeans.kmeans_step(pts, cw, cen)
    cen = np.asarray(cen)
    np.testing.assert_allclose(cen, [10.0, 50.0, 90.0], atol=0.5)
