"""Pallas dense/MLP kernel vs oracle + L2 model shape checks."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import mlp, ref


def make_layer(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(scale=0.2, size=(k, n)).astype(np.float32)
    b = rng.normal(scale=0.1, size=n).astype(np.float32)
    return x, w, b


@pytest.mark.parametrize("m,k,n", [(32, 784, 256), (64, 256, 128), (32, 64, 10), (7, 5, 3)])
@pytest.mark.parametrize("relu", [True, False])
def test_dense_matches_ref(m, k, n, relu):
    x, w, b = make_layer(m, k, n, seed=m + n)
    out_k = np.asarray(mlp.dense(x, w, b, relu=relu))
    out_r = np.asarray(ref.dense_ref(x, w, b, relu=relu))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=64),
    k=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_dense_hypothesis(m, k, n, seed):
    x, w, b = make_layer(m, k, n, seed=seed)
    out_k = np.asarray(mlp.dense(x, w, b, relu=True))
    out_r = np.asarray(ref.dense_ref(x, w, b, relu=True))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-3, atol=1e-3)


def _mlp_params(seed=0):
    rng = np.random.default_rng(seed)
    params = []
    for i in range(4):
        din, dout = model.MLP_DIMS[i], model.MLP_DIMS[i + 1]
        params.append(
            (
                rng.normal(scale=(2.0 / din) ** 0.5, size=(din, dout)).astype(np.float32),
                np.zeros(dout, dtype=np.float32),
            )
        )
    return params


def test_mlp_forward_matches_ref():
    params = _mlp_params()
    rng = np.random.default_rng(1)
    x = rng.uniform(size=(model.MLP_DIMS[0],)).astype(np.float32)
    xb = np.tile(x, (64, 1))
    flat = [a for wb in params for a in wb]
    out_k = np.asarray(model.mlp_forward(xb, *flat))
    out_r = np.asarray(ref.mlp_forward_ref(xb, params))
    assert out_k.shape == (64, 10)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-3, atol=1e-3)


def test_mlp_example_args_shapes():
    args = model.mlp_example_args(64)
    assert args[0].shape == (64, 784)
    assert args[1].shape == (784, 256)
    assert args[-1].shape == (10,)
    assert len(args) == 9
