"""Pallas lasso_cd kernel vs the pure-jnp oracle — the core correctness
signal for L1, with hypothesis sweeping shapes and parameter ranges."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lasso_cd, ref


def make_problem(m, seed, lam1=0.05, lam2=0.0, pad=0):
    rng = np.random.default_rng(seed)
    v = np.sort(rng.uniform(-2.0, 2.0, size=m - pad))
    v = np.unique(v)
    mm = len(v)
    w = np.concatenate([v, np.full(m - mm, v[-1])]).astype(np.float32)
    d = np.concatenate([[v[0]], np.diff(v), np.zeros(m - mm)]).astype(np.float32)
    cw = np.concatenate([np.ones(mm), np.zeros(m - mm)]).astype(np.float32)
    lam = np.array([lam1, lam2], dtype=np.float32)
    alpha = np.ones(m, dtype=np.float32)
    return w, d, cw, lam, alpha


@pytest.mark.parametrize("m", [8, 32, 64, 256])
def test_kernel_matches_ref(m):
    w, d, cw, lam, alpha = make_problem(m, seed=m)
    out_k = np.asarray(lasso_cd.lasso_cd_epoch(w, d, cw, lam, alpha))
    out_r = np.asarray(ref.lasso_cd_epoch_ref(w, d, cw, lam, alpha))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=4, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31),
    lam1=st.floats(min_value=0.0, max_value=2.0),
)
def test_kernel_matches_ref_hypothesis(m, seed, lam1):
    w, d, cw, lam, alpha = make_problem(m, seed=seed, lam1=lam1)
    out_k = np.asarray(lasso_cd.lasso_cd_epoch(w, d, cw, lam, alpha))
    out_r = np.asarray(ref.lasso_cd_epoch_ref(w, d, cw, lam, alpha))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-3, atol=1e-4)


def test_padding_is_inert():
    """Padded rows (cw=0, d=0) must not change real coordinates."""
    w, d, cw, lam, alpha = make_problem(32, seed=7)
    out_real = np.asarray(lasso_cd.lasso_cd_epoch(w, d, cw, lam, alpha))
    wp, dp, cwp, _, alphap = make_problem(64, seed=7, pad=32)
    # Same real prefix by construction.
    np.testing.assert_allclose(wp[:32], w)
    out_pad = np.asarray(lasso_cd.lasso_cd_epoch(wp, dp, cwp, lam, alphap))
    np.testing.assert_allclose(out_pad[:32], out_real, rtol=1e-5, atol=1e-6)


def test_zero_lambda_keeps_exact_start():
    """λ=0 from α=1 (zero loss) must be a fixed point."""
    w, d, cw, lam, alpha = make_problem(48, seed=3, lam1=0.0)
    out = np.asarray(lasso_cd.lasso_cd_epoch(w, d, cw, lam, alpha))
    np.testing.assert_allclose(out, alpha, rtol=1e-5, atol=1e-6)


def test_epoch_reduces_objective():
    w, d, cw, lam, alpha = make_problem(64, seed=9, lam1=0.3)

    def objective(a):
        rec = np.cumsum(d * a)
        return 0.5 * np.sum(cw * (w - rec) ** 2) + lam[0] * np.sum(np.abs(a))

    out = np.asarray(lasso_cd.lasso_cd_epoch(w, d, cw, lam, alpha))
    assert objective(out) <= objective(alpha) + 1e-6


def test_repeated_epochs_sparsify():
    w, d, cw, lam, alpha = make_problem(64, seed=11, lam1=0.8)
    a = jnp.asarray(alpha)
    for _ in range(50):
        a = lasso_cd.lasso_cd_epoch(w, d, cw, lam, a)
    a = np.asarray(a)
    nnz = np.count_nonzero(np.abs(a) > 1e-7)
    assert nnz < 64, "strong lambda must produce sparsity"


def test_negative_l2_increases_sparsity():
    w, d, cw, _, alpha = make_problem(64, seed=13)
    cmin = np.min(np.where(d[:64] != 0, d * d, np.inf)) * 1.0  # scale guard

    def run(lam2):
        lam = np.array([0.4, lam2], dtype=np.float32)
        a = jnp.asarray(alpha)
        for _ in range(60):
            a = lasso_cd.lasso_cd_epoch(w, d, cw, lam, a)
        return np.count_nonzero(np.abs(np.asarray(a)) > 1e-7)

    assert run(0.2 * cmin) <= run(0.0)
