"""AOT pipeline tests: lowering works, manifest is complete and honest."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model


def test_build_entries_cover_all_kinds():
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    kinds = {e[3]["kind"] for e in entries}
    assert kinds == {"lasso_cd", "kmeans", "gmm", "mlp_fwd"}
    assert len(names) == len(set(names)), "artifact names must be unique"
    for m in aot.LASSO_BUCKETS:
        assert f"lasso_cd_m{m}" in names
    for m, k in aot.KMEANS_BUCKETS:
        assert f"kmeans_m{m}_k{k}" in names
    for m, k in aot.GMM_BUCKETS:
        assert f"gmm_m{m}_k{k}" in names


def test_lower_smallest_lasso_to_hlo_text():
    lowered = jax.jit(model.lasso_cd_epochs).lower(*model.lasso_example_args(64))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 1000


def test_manifest_written(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--only", "lasso_cd_m64"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert len(arts) == 1
    a = arts[0]
    assert a["name"] == "lasso_cd_m64"
    assert os.path.exists(tmp_path / a["file"])
    assert [i["shape"] for i in a["inputs"]] == [[64], [64], [64], [2], [64]]
    assert all(i["dtype"] == "float32" for i in a["inputs"])
    assert a["meta"]["epochs_per_call"] == model.EPOCHS_PER_CALL


def test_lasso_epochs_progress_like_single_epochs():
    """The fused EPOCHS_PER_CALL graph equals calling the kernel that many
    times."""
    from compile.kernels import lasso_cd

    rng = np.random.default_rng(0)
    v = np.sort(np.unique(rng.uniform(0, 1, 48))).astype(np.float32)
    m = 64
    w = np.concatenate([v, np.full(m - len(v), v[-1])]).astype(np.float32)
    d = np.concatenate([[v[0]], np.diff(v), np.zeros(m - len(v))]).astype(np.float32)
    cw = np.concatenate([np.ones(len(v)), np.zeros(m - len(v))]).astype(np.float32)
    lam = np.array([0.05, 0.0], dtype=np.float32)
    alpha = np.ones(m, dtype=np.float32)

    fused = np.asarray(model.lasso_cd_epochs(w, d, cw, lam, alpha))
    manual = alpha
    for _ in range(model.EPOCHS_PER_CALL):
        manual = lasso_cd.lasso_cd_epoch(w, d, cw, lam, manual)
    np.testing.assert_allclose(fused, np.asarray(manual), rtol=1e-5, atol=1e-6)


def test_real_manifest_if_present():
    here = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    path = os.path.join(here, "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    manifest = json.loads(open(path).read())
    names = {a["name"] for a in manifest["artifacts"]}
    assert {f"lasso_cd_m{m}" for m in aot.LASSO_BUCKETS} <= names
    for a in manifest["artifacts"]:
        f = os.path.join(here, "artifacts", a["file"])
        assert os.path.exists(f), f"missing {f}"
        assert "HloModule" in open(f).read(200)
